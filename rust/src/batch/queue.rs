//! Job-queue front-end over the batched solve engine — since the service
//! redesign, a one-shot compatibility wrapper over
//! [`crate::service::Service`] (DESIGN.md §8).
//!
//! Heterogeneous jobs (different sizes, generators, scenarios) are grouped
//! by (scenario, compiled bucket), chunked to the largest compiled batch
//! capacity, and each pack is driven through `solve_pack`'s shared forward
//! passes. Results come back per job with timing, so callers can account
//! end-to-end latency per request as well as per-pack amortized step cost.
//! `run_queue` realizes that contract as submit-all → flush → drain on a
//! throwaway `Service` in [`LaunchPolicy::OnFlush`] mode, whose flush-time
//! grouping reproduces the historical pack order and outcomes bit-exact
//! (`rust/tests/batch_equivalence.rs` pins it). Per-pack *transfer stats*
//! deliberately improve: θ uploads once per call through the service's
//! `ThetaCache` rather than once per pack, so packs after the first book
//! lower `exec.h2d_bytes` than pre-service releases.

use crate::batch::solve::BatchCfg;
use crate::coordinator::metrics::exec_stats_json;
use crate::env::Scenario;
use crate::graph::Graph;
use crate::model::Params;
use crate::runtime::{ExecStats, Runtime};
use crate::service::{LaunchCause, LaunchPolicy, Service};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::time::Instant;

/// One solve request.
#[derive(Debug, Clone)]
pub struct Job {
    /// Caller-facing job identifier (echoed in outcomes).
    pub id: String,
    /// Scenario this job solves.
    pub scenario: Scenario,
    /// The instance to solve (moved into the pack's environment).
    pub graph: Graph,
}

/// Per-job outcome.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Job identifier (as submitted).
    pub id: String,
    /// Scenario solved.
    pub scenario: Scenario,
    /// Node count |V| of the job's graph.
    pub nodes: usize,
    /// Undirected edge count |E|.
    pub edges: usize,
    /// Index of the pack this job was solved in.
    pub pack: usize,
    /// Selected node ids (ascending).
    pub solution: Vec<usize>,
    /// Number of selected nodes |S|.
    pub solution_size: usize,
    /// Scenario objective (|S| except MaxCut: cut weight).
    pub objective: f64,
    /// Structural validity of the solution.
    pub valid: bool,
    /// Shared forward passes this job participated in.
    pub evaluations: usize,
    /// Nodes selected in total (>= evaluations under multi-select).
    pub selections: usize,
}

impl JobOutcome {
    /// Render as the JSON object shared by the `oggm batch-solve` report
    /// and the `oggm serve` JSONL stream.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id.as_str())
            .set("scenario", self.scenario.name())
            .set("nodes", self.nodes)
            .set("edges", self.edges)
            .set("pack", self.pack)
            .set("solution", self.solution.clone())
            .set("solution_size", self.solution_size)
            .set("objective", self.objective)
            .set("valid", self.valid)
            .set("evaluations", self.evaluations)
            .set("selections", self.selections)
    }
}

/// Per-pack statistics.
#[derive(Debug, Clone)]
pub struct PackStat {
    /// Pack index within the report.
    pub pack: usize,
    /// Scenario shared by every job in the pack.
    pub scenario: Scenario,
    /// Padded bucket size N of the pack.
    pub bucket_n: usize,
    /// What fired the pack's launch (fill / deadline / max_wait / flush).
    pub cause: LaunchCause,
    /// Number of jobs solved in this pack.
    pub jobs: usize,
    /// Compiled batch capacity the pack opened at.
    pub capacity: usize,
    /// Shared forward passes executed.
    pub rounds: usize,
    /// Compaction repacks performed.
    pub repacks: usize,
    /// Simulated-parallel seconds for the pack.
    pub sim_time: f64,
    /// Wall-clock seconds for the pack.
    pub wall_time: f64,
    /// Bytes moved through collectives.
    pub comm_bytes: u64,
    /// Full re-solve attempts after a retryable fault before this pack
    /// succeeded (0 on the fault-free path; DESIGN.md §11).
    pub retries: usize,
    /// Runtime transfer accounting for this pack (h2d/d2h bytes, stage
    /// executions, exec time — see DESIGN.md §6).
    pub exec: ExecStats,
}

/// Everything `oggm batch-solve` reports.
#[derive(Debug)]
pub struct QueueReport {
    /// Per-job outcomes, in submission order.
    pub outcomes: Vec<JobOutcome>,
    /// Per-pack statistics, in execution order.
    pub packs: Vec<PackStat>,
    /// Wall-clock seconds for the whole queue.
    pub wall_total: f64,
}

impl QueueReport {
    /// Render the report as the `oggm batch-solve` JSON document.
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self.outcomes.iter().map(|o| o.to_json()).collect();
        let packs: Vec<Json> = self
            .packs
            .iter()
            .map(|p| {
                Json::obj()
                    .set("pack", p.pack)
                    .set("scenario", p.scenario.name())
                    .set("bucket_n", p.bucket_n)
                    .set("cause", p.cause.name())
                    .set("jobs", p.jobs)
                    .set("capacity", p.capacity)
                    .set("rounds", p.rounds)
                    .set("repacks", p.repacks)
                    .set("sim_time", p.sim_time)
                    .set("wall_time", p.wall_time)
                    .set("comm_bytes", p.comm_bytes)
                    .set("retries", p.retries)
                    .set("exec", exec_stats_json(&p.exec))
            })
            .collect();
        Json::obj()
            .set("jobs", Json::Arr(jobs))
            .set("packs", Json::Arr(packs))
            .set("wall_total", self.wall_total)
    }
}

/// Group jobs into packs and solve them all. Outcomes are returned in the
/// original job order.
///
/// Compatibility wrapper over [`Service`]: every job is submitted up
/// front, nothing launches before `flush` ([`LaunchPolicy::OnFlush`]), so
/// the (scenario, bucket)-ordered grouping, chunking, and pack numbering
/// are exactly the historical one-shot behavior. Long-lived callers that
/// want incremental admission and streaming outcomes should hold a
/// [`Service`] instead. Where the old implementation panicked on internal
/// invariants ("every job assigned to a pack"), this surfaces contextful
/// errors per job.
pub fn run_queue(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    jobs: &[Job],
) -> Result<QueueReport> {
    run_queue_with(rt, cfg, params, jobs, None)
}

/// [`run_queue`] with an explicit rank transport spec: `Some` routes the
/// rank-parallel engine over TCP worker processes (`--ranks`, DESIGN.md
/// §12) instead of in-process threads. Grouping, pack numbering, and
/// solutions are identical either way — the transport is below the
/// engine's determinism seam.
pub fn run_queue_with(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    jobs: &[Job],
    ranks: Option<&str>,
) -> Result<QueueReport> {
    let wall = Instant::now();
    // OnFlush pins the historical grouping; fail_fast pins the historical
    // error path (an early pack failure must not keep solving packs whose
    // outcomes this call is about to discard).
    let mut svc = Service::with_cfg(rt, params.clone(), *cfg)
        .launch_policy(LaunchPolicy::OnFlush)
        .fail_fast(true)
        .rank_transport(ranks.map(|s| s.to_string()));
    for job in jobs {
        // Admission errors (no compiled bucket fits) fail the whole queue,
        // as the one-shot grouping always did.
        svc.submit(job.clone())?;
    }
    let mut outcomes: Vec<Option<JobOutcome>> = (0..jobs.len()).map(|_| None).collect();
    for ev in svc.drain() {
        let slot = outcomes.get_mut(ev.job.index()).with_context(|| {
            format!("job '{}': service event {} outside the submitted range", ev.id, ev.job)
        })?;
        *slot = Some(ev.result.map_err(|e| anyhow!("job '{}': {e}", ev.id))?);
    }
    let outcomes = outcomes
        .into_iter()
        .enumerate()
        .map(|(ji, o)| {
            o.with_context(|| {
                format!("job '{}': no outcome streamed for it (service bug)", jobs[ji].id)
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(QueueReport { outcomes, packs: svc.take_packs(), wall_total: wall.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let report = QueueReport {
            outcomes: vec![JobOutcome {
                id: "a".into(),
                scenario: Scenario::Mvc,
                nodes: 20,
                edges: 30,
                pack: 0,
                solution: vec![1, 4, 7],
                solution_size: 3,
                objective: 3.0,
                valid: true,
                evaluations: 3,
                selections: 3,
            }],
            packs: vec![PackStat {
                pack: 0,
                scenario: Scenario::Mvc,
                bucket_n: 24,
                cause: LaunchCause::Flush,
                jobs: 1,
                capacity: 1,
                rounds: 3,
                repacks: 0,
                sim_time: 0.5,
                wall_time: 0.6,
                comm_bytes: 1024,
                retries: 1,
                exec: ExecStats {
                    executions: 9,
                    h2d_bytes: 2048,
                    d2h_bytes: 96,
                    ..Default::default()
                },
            }],
            wall_total: 0.7,
        };
        let s = report.to_json().render();
        assert!(s.contains("\"id\":\"a\""), "{s}");
        assert!(s.contains("\"solution\":[1,4,7]"), "{s}");
        assert!(s.contains("\"capacity\":1"), "{s}");
        assert!(s.contains("\"cause\":\"flush\""), "{s}");
        assert!(s.contains("\"wall_total\":0.7"), "{s}");
        // Transfer accounting is surfaced per pack.
        assert!(s.contains("\"executions\":9"), "{s}");
        assert!(s.contains("\"h2d_bytes\":2048"), "{s}");
        assert!(s.contains("\"d2h_bytes\":96"), "{s}");
        assert!(s.contains("\"retries\":1"), "{s}");
    }
}
