//! Job-queue front-end over the batched solve engine: the serve-style
//! entry the ROADMAP's "many concurrent solve requests" north star needs.
//!
//! Heterogeneous jobs (different sizes, generators, scenarios) are grouped
//! by (scenario, compiled bucket), chunked to the largest compiled batch
//! capacity, and each pack is driven through `solve_pack`'s shared forward
//! passes. Results come back per job with timing, so callers can account
//! end-to-end latency per request as well as per-pack amortized step cost.

use crate::batch::solve::{solve_pack, BatchCfg};
use crate::coordinator::metrics::exec_stats_json;
use crate::env::Scenario;
use crate::graph::Graph;
use crate::model::Params;
use crate::runtime::{ExecStats, Runtime};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// One solve request.
#[derive(Debug, Clone)]
pub struct Job {
    /// Caller-facing job identifier (echoed in outcomes).
    pub id: String,
    /// Scenario this job solves.
    pub scenario: Scenario,
    /// The instance to solve (moved into the pack's environment).
    pub graph: Graph,
}

/// Per-job outcome.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Job identifier (as submitted).
    pub id: String,
    /// Scenario solved.
    pub scenario: Scenario,
    /// Node count |V| of the job's graph.
    pub nodes: usize,
    /// Undirected edge count |E|.
    pub edges: usize,
    /// Index of the pack this job was solved in.
    pub pack: usize,
    /// Selected node ids (ascending).
    pub solution: Vec<usize>,
    /// Number of selected nodes |S|.
    pub solution_size: usize,
    /// Scenario objective (|S| except MaxCut: cut weight).
    pub objective: f64,
    /// Structural validity of the solution.
    pub valid: bool,
    /// Shared forward passes this job participated in.
    pub evaluations: usize,
    /// Nodes selected in total (>= evaluations under multi-select).
    pub selections: usize,
}

/// Per-pack statistics.
#[derive(Debug, Clone)]
pub struct PackStat {
    /// Pack index within the report.
    pub pack: usize,
    /// Scenario shared by every job in the pack.
    pub scenario: Scenario,
    /// Padded bucket size N of the pack.
    pub bucket_n: usize,
    /// Number of jobs solved in this pack.
    pub jobs: usize,
    /// Compiled batch capacity the pack opened at.
    pub capacity: usize,
    /// Shared forward passes executed.
    pub rounds: usize,
    /// Compaction repacks performed.
    pub repacks: usize,
    /// Simulated-parallel seconds for the pack.
    pub sim_time: f64,
    /// Wall-clock seconds for the pack.
    pub wall_time: f64,
    /// Bytes moved through collectives.
    pub comm_bytes: u64,
    /// Runtime transfer accounting for this pack (h2d/d2h bytes, stage
    /// executions, exec time — see DESIGN.md §6).
    pub exec: ExecStats,
}

/// Everything `oggm batch-solve` reports.
#[derive(Debug)]
pub struct QueueReport {
    /// Per-job outcomes, in submission order.
    pub outcomes: Vec<JobOutcome>,
    /// Per-pack statistics, in execution order.
    pub packs: Vec<PackStat>,
    /// Wall-clock seconds for the whole queue.
    pub wall_total: f64,
}

impl QueueReport {
    /// Render the report as the `oggm batch-solve` JSON document.
    pub fn to_json(&self) -> Json {
        let jobs: Vec<Json> = self
            .outcomes
            .iter()
            .map(|o| {
                Json::obj()
                    .set("id", o.id.as_str())
                    .set("scenario", o.scenario.name())
                    .set("nodes", o.nodes)
                    .set("edges", o.edges)
                    .set("pack", o.pack)
                    .set("solution", o.solution.clone())
                    .set("solution_size", o.solution_size)
                    .set("objective", o.objective)
                    .set("valid", o.valid)
                    .set("evaluations", o.evaluations)
                    .set("selections", o.selections)
            })
            .collect();
        let packs: Vec<Json> = self
            .packs
            .iter()
            .map(|p| {
                Json::obj()
                    .set("pack", p.pack)
                    .set("scenario", p.scenario.name())
                    .set("bucket_n", p.bucket_n)
                    .set("jobs", p.jobs)
                    .set("capacity", p.capacity)
                    .set("rounds", p.rounds)
                    .set("repacks", p.repacks)
                    .set("sim_time", p.sim_time)
                    .set("wall_time", p.wall_time)
                    .set("comm_bytes", p.comm_bytes)
                    .set("exec", exec_stats_json(&p.exec))
            })
            .collect();
        Json::obj()
            .set("jobs", Json::Arr(jobs))
            .set("packs", Json::Arr(packs))
            .set("wall_total", self.wall_total)
    }
}

/// Group jobs into packs and solve them all. Outcomes are returned in the
/// original job order.
pub fn run_queue(
    rt: &Runtime,
    cfg: &BatchCfg,
    params: &Params,
    jobs: &[Job],
) -> Result<QueueReport> {
    let wall = Instant::now();
    let p = cfg.engine.p;

    // Group by (scenario, compiled bucket); BTreeMap keeps pack order
    // deterministic across runs.
    let mut groups: BTreeMap<(Scenario, usize), Vec<usize>> = BTreeMap::new();
    for (ji, job) in jobs.iter().enumerate() {
        let bucket = rt
            .manifest
            .bucket_for_any_batch(job.graph.n, p)
            .with_context(|| format!("job '{}' (|V|={})", job.id, job.graph.n))?;
        groups.entry((job.scenario, bucket)).or_default().push(ji);
    }

    let mut outcomes: Vec<Option<JobOutcome>> = (0..jobs.len()).map(|_| None).collect();
    let mut packs = Vec::new();
    for ((scenario, bucket), members) in groups {
        let part_ni = bucket / p;
        let caps = rt.manifest.batch_sizes(bucket, part_ni);
        let max_cap = *caps.last().expect("bucket_for_any_batch guarantees an entry");
        for chunk in members.chunks(max_cap) {
            let pack_idx = packs.len();
            let graphs: Vec<Graph> = chunk.iter().map(|&ji| jobs[ji].graph.clone()).collect();
            let res = solve_pack(rt, cfg, params, scenario, graphs, bucket)
                .with_context(|| format!("pack {pack_idx} ({scenario}, N={bucket})"))?;
            for (slot, &ji) in chunk.iter().enumerate() {
                let r = &res.per_graph[slot];
                let solution: Vec<usize> =
                    r.solution.iter().enumerate().filter(|(_, &b)| b).map(|(v, _)| v).collect();
                outcomes[ji] = Some(JobOutcome {
                    id: jobs[ji].id.clone(),
                    scenario,
                    nodes: jobs[ji].graph.n,
                    edges: jobs[ji].graph.m,
                    pack: pack_idx,
                    solution,
                    solution_size: r.solution_size,
                    objective: r.objective,
                    valid: r.valid,
                    evaluations: r.evaluations,
                    selections: r.selections,
                });
            }
            packs.push(PackStat {
                pack: pack_idx,
                scenario,
                bucket_n: bucket,
                jobs: chunk.len(),
                capacity: res.initial_capacity,
                rounds: res.rounds,
                repacks: res.repacks,
                sim_time: res.sim_total,
                wall_time: res.wall_total,
                comm_bytes: res.timing.comm_bytes,
                exec: res.exec,
            });
        }
    }

    Ok(QueueReport {
        outcomes: outcomes.into_iter().map(|o| o.expect("every job assigned to a pack")).collect(),
        packs,
        wall_total: wall.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let report = QueueReport {
            outcomes: vec![JobOutcome {
                id: "a".into(),
                scenario: Scenario::Mvc,
                nodes: 20,
                edges: 30,
                pack: 0,
                solution: vec![1, 4, 7],
                solution_size: 3,
                objective: 3.0,
                valid: true,
                evaluations: 3,
                selections: 3,
            }],
            packs: vec![PackStat {
                pack: 0,
                scenario: Scenario::Mvc,
                bucket_n: 24,
                jobs: 1,
                capacity: 1,
                rounds: 3,
                repacks: 0,
                sim_time: 0.5,
                wall_time: 0.6,
                comm_bytes: 1024,
                exec: ExecStats {
                    executions: 9,
                    h2d_bytes: 2048,
                    d2h_bytes: 96,
                    ..Default::default()
                },
            }],
            wall_total: 0.7,
        };
        let s = report.to_json().render();
        assert!(s.contains("\"id\":\"a\""), "{s}");
        assert!(s.contains("\"solution\":[1,4,7]"), "{s}");
        assert!(s.contains("\"capacity\":1"), "{s}");
        assert!(s.contains("\"wall_total\":0.7"), "{s}");
        // Transfer accounting is surfaced per pack.
        assert!(s.contains("\"executions\":9"), "{s}");
        assert!(s.contains("\"h2d_bytes\":2048"), "{s}");
        assert!(s.contains("\"d2h_bytes\":96"), "{s}");
    }
}
