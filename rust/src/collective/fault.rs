//! Deterministic fault injection for the rank-parallel engine
//! (DESIGN.md §11).
//!
//! A [`FaultPlan`] is a small, seeded-by-construction script of *exactly
//! when* a rank misbehaves: "rank 1, forward step 3, panic" or "rank 0,
//! collective op 2 matching `all_reduce(deposit)`, error". Plans are
//! parsed from a string (the `--fault-plan` CLI flag or the
//! `OGGM_FAULT_PLAN` environment variable) and threaded into
//! [`crate::parallel`] workers and [`crate::collective::comm`] handles, so
//! every recovery path — worker death, collective abort, slow rank — is
//! replayable in tests without sleeps or flaky timing.
//!
//! Grammar (entries separated by `;`, fields by `,`):
//!
//! ```text
//! rank=1,step=3,kind=panic
//! rank=0,kind=err,op=all_reduce(deposit)
//! rank=1,step=0,kind=slow,ms=15
//! rank=1,kind=drop,frame=2
//! rank=0,kind=delay,ms=30
//! rank=1,kind=disconnect,frame=4
//! rank=0,kind=stall
//! ```
//!
//! - `rank` (required): which rank the fault targets.
//! - `kind` (required): `panic` (thread dies → pool replaces the rank),
//!   `err` (recoverable `Err` response), `slow` (bounded sleep,
//!   `ms=` duration, default 20ms), the transport faults `drop` (a
//!   coordinator→rank frame is discarded; the pack retries) and `delay`
//!   (a frame is stalled `ms=` before sending), or the worker-side
//!   liveness faults `disconnect` (the worker closes its socket and
//!   exits — a scripted `kill -9`) and `stall` (the worker stops
//!   sending frames, heartbeats included — a scripted hang).
//! - `step` (optional): the 0-based occurrence counter at the injection
//!   site — forward steps for worker faults, `phase()` calls on that
//!   rank's handle for collective faults. Omitted = first opportunity.
//! - `op` (optional): a collective phase name (e.g. `barrier`,
//!   `all_gather(deposit)`). Present = the fault fires inside
//!   `Communicator::phase`; absent = it fires at the worker's forward
//!   step. The two sites keep independent counters.
//! - `frame` (optional, transport kinds only): the 0-based frame
//!   counter on that rank's coordinator→worker link. Transport specs
//!   fire at the send site ([`FaultPlan::fire_transport`]) and never
//!   alias with the worker/collective sites; non-transport specs must
//!   not set `frame=`.
//!
//! Every spec is **one-shot**: it fires at most once per plan instance
//! (atomically), so a retried pack after recovery runs fault-free and can
//! be asserted bit-identical to an unfaulted run.

use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What the injected fault does at its trigger point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the worker thread (simulates a crashed rank; the pool's
    /// supervisor replaces it).
    Panic,
    /// Return a recoverable error (simulates a transient device error;
    /// the worker thread survives).
    Err,
    /// Sleep for the given duration (simulates a straggler rank; no
    /// error, just latency attributed to that rank).
    Slow(Duration),
    /// Discard one coordinator→rank transport frame (simulates a lost
    /// message on the wire; the pool aborts the pack, which retries).
    Drop,
    /// Stall one coordinator→rank transport frame before sending
    /// (simulates wire latency; no error).
    Delay(Duration),
    /// Close the worker's coordinator socket and exit (simulates a
    /// `kill -9`ed worker process; the coordinator's liveness layer
    /// detects the dead link and opens the rejoin window). Worker-side,
    /// fired at the received-request counter ([`FaultPlan::fire_liveness`]).
    Disconnect,
    /// The worker stops sending frames — responses *and* heartbeats —
    /// while still reading (simulates a hung process; the coordinator's
    /// `--rank-timeout` deadline fires). Worker-side like `disconnect`.
    Stall,
}

/// One scripted fault: where (rank, site, occurrence) and what
/// ([`FaultKind`]). One-shot: `fired` flips on first match.
#[derive(Debug)]
pub struct FaultSpec {
    /// Target rank.
    pub rank: usize,
    /// 0-based occurrence counter at the injection site (None = first
    /// opportunity).
    pub step: Option<usize>,
    /// Collective phase-op name; None targets the worker forward step.
    pub op: Option<String>,
    /// 0-based frame counter on the rank's transport link (transport
    /// and liveness kinds only; None = first frame after the plan is
    /// armed). `drop`/`delay` count coordinator→rank sends;
    /// `disconnect`/`stall` count worker-side receives.
    pub frame: Option<u64>,
    /// What happens when the spec matches.
    pub kind: FaultKind,
    fired: AtomicBool,
}

/// A parsed, shareable fault script (see module docs). Cloned by `Arc`
/// into every worker thread and communicator handle so the one-shot
/// accounting is global across the pool.
#[derive(Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse a plan string (see module docs for the grammar). An empty
    /// string parses as an empty (inert) plan.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut specs = Vec::new();
        for entry in s.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            specs.push(Self::parse_entry(entry).with_context(|| format!("fault spec '{entry}'"))?);
        }
        Ok(FaultPlan { specs })
    }

    fn parse_entry(entry: &str) -> Result<FaultSpec> {
        let mut rank = None;
        let mut step = None;
        let mut op = None;
        let mut frame = None;
        let mut kind = None;
        let mut ms = 20u64;
        for field in entry.split(',').map(str::trim).filter(|f| !f.is_empty()) {
            let (k, v) = field
                .split_once('=')
                .with_context(|| format!("field '{field}' is not key=value"))?;
            match k.trim() {
                "rank" => rank = Some(v.trim().parse::<usize>().context("rank")?),
                "step" => step = Some(v.trim().parse::<usize>().context("step")?),
                "op" => op = Some(v.trim().to_string()),
                "frame" => frame = Some(v.trim().parse::<u64>().context("frame")?),
                "kind" => {
                    kind = Some(match v.trim() {
                        "panic" => FaultKind::Panic,
                        "err" => FaultKind::Err,
                        "slow" => FaultKind::Slow(Duration::ZERO), // ms applied below
                        "drop" => FaultKind::Drop,
                        "delay" => FaultKind::Delay(Duration::ZERO), // ms applied below
                        "disconnect" => FaultKind::Disconnect,
                        "stall" => FaultKind::Stall,
                        other => {
                            bail!(
                                "unknown kind '{other}' (known: panic, err, slow, drop, \
                                 delay, disconnect, stall)"
                            )
                        }
                    })
                }
                "ms" => ms = v.trim().parse::<u64>().context("ms")?,
                other => {
                    bail!("unknown field '{other}' (known: rank, step, op, kind, ms, frame)")
                }
            }
        }
        let rank = rank.context("missing rank=")?;
        let mut kind = kind.context("missing kind=")?;
        if let FaultKind::Slow(_) = kind {
            kind = FaultKind::Slow(Duration::from_millis(ms));
        }
        if let FaultKind::Delay(_) = kind {
            kind = FaultKind::Delay(Duration::from_millis(ms));
        }
        let transport = matches!(
            kind,
            FaultKind::Drop | FaultKind::Delay(_) | FaultKind::Disconnect | FaultKind::Stall
        );
        if transport && (op.is_some() || step.is_some()) {
            bail!(
                "transport kinds (drop, delay, disconnect, stall) address frames: \
                 use frame=, not op=/step="
            );
        }
        if !transport && frame.is_some() {
            bail!("frame= only applies to transport kinds (drop, delay, disconnect, stall)");
        }
        Ok(FaultSpec { rank, step, op, frame, kind, fired: AtomicBool::new(false) })
    }

    /// Parse the `OGGM_FAULT_PLAN` environment variable, if set and
    /// non-empty. Invalid plans error loudly rather than silently running
    /// fault-free.
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>> {
        match std::env::var("OGGM_FAULT_PLAN") {
            Ok(s) if !s.trim().is_empty() => {
                let plan = FaultPlan::parse(&s).context("OGGM_FAULT_PLAN")?;
                Ok(Some(Arc::new(plan)))
            }
            _ => Ok(None),
        }
    }

    /// Number of scripted faults (fired or not).
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when the plan scripts nothing.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Check (and atomically consume) a fault at an injection site.
    ///
    /// `rank` is the caller's rank, `step` the caller's 0-based counter at
    /// this site, `op` the collective phase name (None at the worker
    /// forward-step site). Returns the [`FaultKind`] to act out, or None.
    /// A spec with `op` set only matches that phase name; a spec without
    /// `op` only matches the forward-step site — the two never alias.
    /// Transport specs (`drop`/`delay`) never fire here; they belong to
    /// [`FaultPlan::fire_transport`].
    pub fn fire(&self, rank: usize, step: usize, op: Option<&str>) -> Option<FaultKind> {
        for spec in &self.specs {
            if matches!(
                spec.kind,
                FaultKind::Drop
                    | FaultKind::Delay(_)
                    | FaultKind::Disconnect
                    | FaultKind::Stall
            ) {
                continue;
            }
            if spec.rank != rank {
                continue;
            }
            if spec.op.as_deref() != op {
                continue;
            }
            if let Some(want) = spec.step {
                if want != step {
                    continue;
                }
            }
            if spec
                .fired
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(spec.kind);
            }
        }
        None
    }

    /// Check (and atomically consume) a *transport* fault at the frame
    /// send site: `rank` is the addressed rank, `frame` the 0-based
    /// count of frames sent to it on this link. Only `drop`/`delay`
    /// specs match; a spec without `frame=` matches the first frame
    /// sent after the plan is armed. One-shot like every spec.
    pub fn fire_transport(&self, rank: usize, frame: u64) -> Option<FaultKind> {
        for spec in &self.specs {
            if !matches!(spec.kind, FaultKind::Drop | FaultKind::Delay(_)) {
                continue;
            }
            if spec.rank != rank {
                continue;
            }
            if let Some(want) = spec.frame {
                if want != frame {
                    continue;
                }
            }
            if spec
                .fired
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(spec.kind);
            }
        }
        None
    }

    /// Check (and atomically consume) a *liveness* fault at the worker's
    /// receive site: `rank` is this worker's rank, `frame` the 0-based
    /// count of requests it has received over its coordinator link. Only
    /// `disconnect`/`stall` specs match — the worker-side siblings of
    /// the coordinator-side `drop`/`delay` — and like them a spec
    /// without `frame=` matches the first opportunity. Never aliases
    /// with [`FaultPlan::fire`] or [`FaultPlan::fire_transport`].
    pub fn fire_liveness(&self, rank: usize, frame: u64) -> Option<FaultKind> {
        for spec in &self.specs {
            if !matches!(spec.kind, FaultKind::Disconnect | FaultKind::Stall) {
                continue;
            }
            if spec.rank != rank {
                continue;
            }
            if let Some(want) = spec.frame {
                if want != frame {
                    continue;
                }
            }
            if spec
                .fired
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(spec.kind);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_grammar() {
        let plan = FaultPlan::parse(
            "rank=1,step=3,kind=panic; rank=0,kind=err,op=all_reduce(deposit); \
             rank=1,step=0,kind=slow,ms=15",
        )
        .unwrap();
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.fire(1, 3, None), Some(FaultKind::Panic));
        assert_eq!(plan.fire(0, 7, Some("all_reduce(deposit)")), Some(FaultKind::Err));
        assert_eq!(plan.fire(1, 0, None), Some(FaultKind::Slow(Duration::from_millis(15))));
    }

    #[test]
    fn specs_are_one_shot() {
        let plan = FaultPlan::parse("rank=0,step=2,kind=err").unwrap();
        assert_eq!(plan.fire(0, 2, None), Some(FaultKind::Err));
        assert_eq!(plan.fire(0, 2, None), None, "a spec fires at most once");
    }

    #[test]
    fn sites_never_alias() {
        // An op-targeted spec does not fire at the forward-step site and
        // vice versa, even with matching rank/step.
        let plan = FaultPlan::parse("rank=0,step=1,kind=err,op=barrier; rank=1,step=1,kind=err")
            .unwrap();
        assert_eq!(plan.fire(0, 1, None), None);
        assert_eq!(plan.fire(0, 1, Some("all_gather(deposit)")), None);
        assert_eq!(plan.fire(0, 1, Some("barrier")), Some(FaultKind::Err));
        assert_eq!(plan.fire(1, 1, Some("barrier")), None);
        assert_eq!(plan.fire(1, 1, None), Some(FaultKind::Err));
    }

    #[test]
    fn omitted_step_matches_first_opportunity_only_once() {
        let plan = FaultPlan::parse("rank=2,kind=panic").unwrap();
        assert_eq!(plan.fire(2, 0, None), Some(FaultKind::Panic));
        assert_eq!(plan.fire(2, 1, None), None);
    }

    #[test]
    fn empty_and_whitespace_plans_are_inert() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ").unwrap().is_empty());
        assert_eq!(FaultPlan::default().fire(0, 0, None), None);
    }

    #[test]
    fn bad_plans_error_with_context() {
        for bad in [
            "rank=1",                    // missing kind
            "kind=panic",                // missing rank
            "rank=x,kind=panic",         // bad rank
            "rank=1,kind=explode",       // unknown kind
            "rank=1,kind=err,who=me",    // unknown field
            "rank=1 kind=err",           // not key=value
            "rank=1,kind=drop,op=barrier", // transport kind with op=
            "rank=1,kind=delay,step=2",  // transport kind with step=
            "rank=1,kind=err,frame=0",   // frame= on a non-transport kind
            "rank=1,kind=disconnect,step=1", // liveness kind with step=
            "rank=0,kind=stall,op=barrier",  // liveness kind with op=
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should fail to parse");
        }
    }

    #[test]
    fn transport_faults_parse_and_fire_at_the_frame_site() {
        let plan =
            FaultPlan::parse("rank=1,kind=drop,frame=2; rank=0,kind=delay,ms=7").unwrap();
        assert_eq!(plan.len(), 2);
        // Frame-addressed drop: only rank 1, only frame 2, one shot.
        assert_eq!(plan.fire_transport(1, 0), None);
        assert_eq!(plan.fire_transport(1, 2), Some(FaultKind::Drop));
        assert_eq!(plan.fire_transport(1, 2), None, "transport specs are one-shot");
        // Frame omitted: first opportunity on that rank's link.
        assert_eq!(plan.fire_transport(0, 5), Some(FaultKind::Delay(Duration::from_millis(7))));
        assert_eq!(plan.fire_transport(0, 6), None);
    }

    #[test]
    fn transport_and_execution_sites_never_alias() {
        let plan = FaultPlan::parse("rank=0,kind=drop; rank=0,kind=err").unwrap();
        // The drop spec is invisible to the worker/collective site …
        assert_eq!(plan.fire(0, 0, None), Some(FaultKind::Err));
        assert_eq!(plan.fire(0, 1, None), None);
        // … and the err spec is invisible to the frame site.
        assert_eq!(plan.fire_transport(0, 0), Some(FaultKind::Drop));
        assert_eq!(plan.fire_transport(0, 1), None);
    }

    #[test]
    fn liveness_faults_parse_and_fire_at_the_worker_receive_site() {
        let plan =
            FaultPlan::parse("rank=1,kind=disconnect,frame=3; rank=0,kind=stall").unwrap();
        assert_eq!(plan.len(), 2);
        // Frame-addressed disconnect: only rank 1, only frame 3, one shot.
        assert_eq!(plan.fire_liveness(1, 0), None);
        assert_eq!(plan.fire_liveness(1, 3), Some(FaultKind::Disconnect));
        assert_eq!(plan.fire_liveness(1, 3), None, "liveness specs are one-shot");
        // Frame omitted: first opportunity on that worker.
        assert_eq!(plan.fire_liveness(0, 2), Some(FaultKind::Stall));
        assert_eq!(plan.fire_liveness(0, 3), None);
    }

    #[test]
    fn liveness_site_never_aliases_with_the_other_sites() {
        let plan = FaultPlan::parse("rank=0,kind=disconnect; rank=0,kind=drop").unwrap();
        // The disconnect spec is invisible to the coordinator frame-send
        // site and the worker/collective site …
        assert_eq!(plan.fire_transport(0, 0), Some(FaultKind::Drop));
        assert_eq!(plan.fire_transport(0, 1), None);
        assert_eq!(plan.fire(0, 0, None), None);
        // … and only the liveness site consumes it.
        assert_eq!(plan.fire_liveness(0, 0), Some(FaultKind::Disconnect));
        assert_eq!(plan.fire_liveness(0, 1), None);
    }
}
