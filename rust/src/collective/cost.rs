//! α–β communication cost model (the paper's Eq. 3/5 communication terms).
//!
//! The lockstep engine attributes `alpha·log2(P) + beta·bytes` of simulated
//! time to each collective, mirroring how §5.1 models MPI_All_reduce /
//! MPI_All_gather over NCCL on a Summit node. Defaults are NVLink-class
//! numbers (α = 5 µs, 50 GB/s effective per-GPU bandwidth).

/// Latency/bandwidth model for simulated collectives.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-collective latency in seconds (α).
    pub alpha: f64,
    /// Seconds per byte (β = 1 / bandwidth).
    pub beta: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel { alpha: 5e-6, beta: 1.0 / 50e9 }
    }
}

impl CostModel {
    /// Zero-cost model (for pure-compute measurements).
    pub fn free() -> CostModel {
        CostModel { alpha: 0.0, beta: 0.0 }
    }

    /// Ring all-reduce of `bytes` per rank over p ranks.
    pub fn all_reduce(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        self.alpha * (p as f64).log2() + self.beta * bytes as f64
    }

    /// All-gather where each rank contributes `bytes_per_rank`.
    pub fn all_gather(&self, p: usize, bytes_per_rank: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        self.alpha * (p as f64).log2() + self.beta * (bytes_per_rank * (p - 1)) as f64
    }

    /// Broadcast of `bytes` from the root.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        self.alpha * (p as f64).log2() + self.beta * bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::default();
        assert_eq!(m.all_reduce(1, 1 << 20), 0.0);
        assert_eq!(m.all_gather(1, 1 << 20), 0.0);
    }

    #[test]
    fn monotone_in_p_and_bytes() {
        let m = CostModel::default();
        assert!(m.all_reduce(4, 1000) > m.all_reduce(2, 1000));
        assert!(m.all_reduce(2, 2000) > m.all_reduce(2, 1000));
        assert!(m.all_gather(4, 1000) > m.all_gather(2, 1000));
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.all_reduce(6, 123456), 0.0);
        assert_eq!(m.broadcast(6, 123456), 0.0);
    }
}
