//! Collective communication substrate (the NCCL / torch.distributed
//! substitute, DESIGN.md §3/§9).
//!
//! `Communicator` implements barrier / all-reduce / all-gather / broadcast
//! over P participants with generation-based synchronization, a chunked
//! rank-order-deterministic all-reduce, and an abort path that turns a
//! failed rank into contextful errors instead of a deadlock; it is the
//! transport of the rank-parallel engine (`crate::parallel`) and is
//! validated standalone under real threads. `cost` implements the paper's
//! α–β communication model (Eq. 3/5) used by the lockstep engine to
//! attribute simulated communication time.

/// Threaded P-way collectives (all-reduce / all-gather / abort).
pub mod comm;
/// α–β communication cost model (DESIGN.md §3).
pub mod cost;
/// Deterministic fault injection plans (DESIGN.md §11).
pub mod fault;

pub use comm::{CommError, CommResult, Communicator};
pub use cost::CostModel;
pub use fault::{FaultKind, FaultPlan};
