//! Collective communication substrate (the NCCL / torch.distributed
//! substitute, DESIGN.md §3).
//!
//! `Communicator` implements barrier / all-reduce / all-gather / broadcast
//! over P participants with generation-based synchronization; it is used by
//! the threaded worker engine and validated standalone under real threads.
//! `cost` implements the paper's α–β communication model (Eq. 3/5) used by
//! the lockstep engine to attribute simulated communication time.

/// Threaded P-way collectives (all-reduce / all-gather).
pub mod comm;
/// α–β communication cost model (DESIGN.md §3).
pub mod cost;

pub use comm::Communicator;
pub use cost::CostModel;
