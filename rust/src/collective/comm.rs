//! Generation-barrier shared-memory collectives.
//!
//! All P participants call the same collective in the same order (the SPMD
//! discipline of Alg. 2-5). Each collective is two phases: contribute
//! (under the mutex) then, once all P arrived, consume. A generation
//! counter prevents a fast rank from racing into the next collective.

use std::sync::{Arc, Condvar, Mutex};

struct State {
    p: usize,
    arrived: usize,
    generation: u64,
    /// Accumulation buffer for all-reduce (len set by first arriver).
    acc: Vec<f32>,
    /// Gather buffer: per-rank parts.
    parts: Vec<Vec<f32>>,
    /// Bytes moved per rank (for metrics / the α–β model).
    bytes_total: u64,
    ops_total: u64,
}

/// A P-way collective communicator. Clone one handle per participant.
#[derive(Clone)]
pub struct Communicator {
    inner: Arc<(Mutex<State>, Condvar)>,
    /// This handle's rank (0..P).
    pub rank: usize,
}

impl Communicator {
    /// Create handles for all P ranks.
    pub fn create(p: usize) -> Vec<Communicator> {
        assert!(p >= 1);
        let inner = Arc::new((
            Mutex::new(State {
                p,
                arrived: 0,
                generation: 0,
                acc: Vec::new(),
                parts: vec![Vec::new(); p],
                bytes_total: 0,
                ops_total: 0,
            }),
            Condvar::new(),
        ));
        (0..p).map(|rank| Communicator { inner: inner.clone(), rank }).collect()
    }

    /// Number of participating ranks P.
    pub fn p(&self) -> usize {
        self.inner.0.lock().unwrap().p
    }

    /// (total bytes sent+received across ranks, number of collectives).
    pub fn traffic(&self) -> (u64, u64) {
        let s = self.inner.0.lock().unwrap();
        (s.bytes_total, s.ops_total)
    }

    /// Barrier: returns once all P ranks have arrived.
    pub fn barrier(&self) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        let gen = s.generation;
        s.arrived += 1;
        if s.arrived == s.p {
            s.arrived = 0;
            s.generation += 1;
            cv.notify_all();
        } else {
            while s.generation == gen {
                s = cv.wait(s).unwrap();
            }
        }
    }

    /// All-reduce (sum) in place: after return, `buf` on every rank holds
    /// the element-wise sum over ranks (Alg. 2 line 12 / Alg. 3 line 5).
    pub fn all_reduce_sum(&self, buf: &mut [f32]) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        let gen = s.generation;
        if s.acc.is_empty() {
            s.acc = vec![0.0; buf.len()];
        }
        assert_eq!(s.acc.len(), buf.len(), "all_reduce length mismatch across ranks");
        for (a, &x) in s.acc.iter_mut().zip(buf.iter()) {
            *a += x;
        }
        s.bytes_total += 4 * buf.len() as u64;
        s.arrived += 1;
        if s.arrived == s.p {
            s.arrived = 0;
            s.generation += 1;
            s.ops_total += 1;
            cv.notify_all();
        } else {
            while s.generation == gen {
                s = cv.wait(s).unwrap();
            }
        }
        // Consume phase: every rank copies the sum out; the trailing
        // barrier (`finish_reduce`) clears `acc` only after all have read.
        buf.copy_from_slice(&s.acc);
        drop(s);
        self.finish_reduce();
    }

    /// Second barrier ensuring every rank copied out before acc is reused.
    fn finish_reduce(&self) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        let gen = s.generation;
        s.arrived += 1;
        if s.arrived == s.p {
            s.arrived = 0;
            s.generation += 1;
            s.acc.clear();
            cv.notify_all();
        } else {
            while s.generation == gen {
                s = cv.wait(s).unwrap();
            }
        }
    }

    /// All-gather: each rank contributes `part`; returns the concatenation
    /// ordered by rank (Alg. 4 line 6).
    pub fn all_gather(&self, part: &[f32]) -> Vec<f32> {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        let gen = s.generation;
        let rank = self.rank;
        s.parts[rank] = part.to_vec();
        s.bytes_total += 4 * part.len() as u64;
        s.arrived += 1;
        if s.arrived == s.p {
            s.arrived = 0;
            s.generation += 1;
            s.ops_total += 1;
            cv.notify_all();
        } else {
            while s.generation == gen {
                s = cv.wait(s).unwrap();
            }
        }
        let out: Vec<f32> = s.parts.iter().flat_map(|p| p.iter().copied()).collect();
        drop(s);
        // Ensure all ranks consumed before parts are overwritten.
        self.barrier();
        out
    }

    /// Broadcast from rank 0.
    pub fn broadcast(&self, buf: &mut Vec<f32>) {
        let (lock, cv) = &*self.inner;
        let mut s = lock.lock().unwrap();
        let gen = s.generation;
        if self.rank == 0 {
            s.acc = buf.clone();
            s.bytes_total += 4 * buf.len() as u64;
        }
        s.arrived += 1;
        if s.arrived == s.p {
            s.arrived = 0;
            s.generation += 1;
            s.ops_total += 1;
            cv.notify_all();
        } else {
            while s.generation == gen {
                s = cv.wait(s).unwrap();
            }
        }
        if self.rank != 0 {
            *buf = s.acc.clone();
        }
        drop(s);
        self.finish_reduce();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ranks<F: Fn(Communicator) + Send + Sync + Clone + 'static>(p: usize, f: F) {
        let comms = Communicator::create(p);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                std::thread::spawn(move || f(c))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn all_reduce_sums() {
        run_ranks(4, |c| {
            let mut buf = vec![c.rank as f32, 1.0, -(c.rank as f32)];
            c.all_reduce_sum(&mut buf);
            assert_eq!(buf, vec![6.0, 4.0, -6.0]);
        });
    }

    #[test]
    fn repeated_all_reduce_no_bleed() {
        run_ranks(3, |c| {
            for round in 0..20 {
                let mut buf = vec![(c.rank + round) as f32];
                c.all_reduce_sum(&mut buf);
                assert_eq!(buf[0], (3 * round + 3) as f32, "round {round}");
            }
        });
    }

    #[test]
    fn all_gather_orders_by_rank() {
        run_ranks(3, |c| {
            let part = vec![c.rank as f32 * 10.0, c.rank as f32 * 10.0 + 1.0];
            let out = c.all_gather(&part);
            assert_eq!(out, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        });
    }

    #[test]
    fn broadcast_from_root() {
        run_ranks(4, |c| {
            let mut buf = if c.rank == 0 { vec![3.5, -1.0] } else { vec![0.0; 2] };
            c.broadcast(&mut buf);
            assert_eq!(buf, vec![3.5, -1.0]);
        });
    }

    #[test]
    fn single_rank_degenerates() {
        let comms = Communicator::create(1);
        let c = &comms[0];
        let mut buf = vec![2.0];
        c.all_reduce_sum(&mut buf);
        assert_eq!(buf, vec![2.0]);
        assert_eq!(c.all_gather(&[1.0, 2.0]), vec![1.0, 2.0]);
        c.barrier();
    }

    #[test]
    fn traffic_accounting() {
        run_ranks(2, |c| {
            let mut buf = vec![0.0; 8];
            c.all_reduce_sum(&mut buf);
            let _ = c.all_gather(&buf[..4]);
        });
        // Recreate to read counters deterministically on one handle.
        let comms = Communicator::create(2);
        let c0 = comms[0].clone();
        let c1 = comms[1].clone();
        let t = std::thread::spawn(move || {
            let mut b = vec![1.0f32; 8];
            c1.all_reduce_sum(&mut b);
        });
        let mut b = vec![1.0f32; 8];
        c0.all_reduce_sum(&mut b);
        t.join().unwrap();
        let (bytes, ops) = c0.traffic();
        assert_eq!(ops, 1);
        assert_eq!(bytes, 2 * 8 * 4);
    }

    #[test]
    fn interleaved_mixed_collectives() {
        run_ranks(4, |c| {
            for round in 0..10 {
                c.barrier();
                let mut buf = vec![1.0f32; 5];
                c.all_reduce_sum(&mut buf);
                assert!(buf.iter().all(|&x| x == 4.0));
                let g = c.all_gather(&[c.rank as f32]);
                assert_eq!(g, vec![0.0, 1.0, 2.0, 3.0], "round {round}");
                let mut b = vec![round as f32];
                c.broadcast(&mut b);
                assert_eq!(b[0], round as f32);
            }
        });
    }
}
