//! Generation-barrier shared-memory collectives.
//!
//! All P participants call the same collective in the same order (the SPMD
//! discipline of Alg. 2-5). Each collective is phased: contribute into a
//! per-rank deposit slot (its own mutex — no contention), synchronize on a
//! generation barrier, then consume. A generation counter prevents a fast
//! rank from racing into the next collective.
//!
//! Two properties the rank-parallel engine (DESIGN.md §9) relies on:
//!
//! - **Deterministic, chunked all-reduce.** The reduction is computed in
//!   *rank order* (chunk `r` is `slot0 + slot1 + … + slotP−1`, left to
//!   right), so the f32 summation order is identical to the lockstep
//!   engine's sequential per-shard `add_assign` — scores and gradients
//!   match across engines to the bit, not just to tolerance. Each rank
//!   reduces its own 1/P chunk of the buffer concurrently, so the work
//!   parallelizes instead of serializing the whole payload under one
//!   mutex.
//! - **Abort instead of deadlock.** A rank that fails mid-collective calls
//!   [`Communicator::abort`]; every waiter wakes immediately and every
//!   in-flight or subsequent collective returns a contextful
//!   [`CommError`] instead of blocking forever on the condvar. Locks are
//!   poison-tolerant (state is plain counters/buffers), so a *panicking*
//!   participant cannot cascade panics through the survivors either.

use crate::collective::fault::{FaultKind, FaultPlan};
use crate::transport::msg::CollOp;
use crate::transport::tcp::{RemoteComm, RemoteIo};
use std::cell::Cell;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Error surfaced by a collective after a participant aborted: identifies
/// the failing rank, its reason, and the operation the caller was in.
#[derive(Debug, Clone)]
pub struct CommError {
    /// Rank that reported the failure via [`Communicator::abort`].
    pub rank: usize,
    /// The reason string passed to `abort`.
    pub reason: String,
    /// The collective phase the caller was in when the abort surfaced.
    pub op: &'static str,
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "collective {} aborted by rank {}: {}",
            self.op, self.rank, self.reason
        )
    }
}

impl std::error::Error for CommError {}

/// Collective result type ([`CommError`] converts into `anyhow::Error`).
pub type CommResult<T> = std::result::Result<T, CommError>;

struct Ctl {
    arrived: usize,
    generation: u64,
    /// Set once by the first `abort`; never cleared — a failed group is
    /// permanently failed (callers recover by creating a new group).
    aborted: Option<(usize, String)>,
    /// Bytes moved per rank (for metrics / the α–β model).
    bytes_total: u64,
    ops_total: u64,
}

struct Shared {
    p: usize,
    ctl: Mutex<Ctl>,
    cv: Condvar,
    /// Per-rank deposit slots: each rank writes only its own, so deposits
    /// never contend on a shared lock.
    slots: Vec<Mutex<Vec<f32>>>,
    /// Per-rank reduction outputs: rank r owns the chunk it reduced.
    reduced: Vec<Mutex<Vec<f32>>>,
}

/// Where a handle's collectives actually run: the in-process shared
/// deposit slots, or a TCP worker's hub-folded round trips through the
/// coordinator (DESIGN.md §12). Both fold in rank order, so results
/// are bitwise identical across backends.
#[derive(Clone)]
enum Backend {
    /// Shared-memory deposit slots (all ranks in one process).
    Local(Arc<Shared>),
    /// Frames to the coordinator's collective hub (worker process).
    Remote(Arc<RemoteComm>),
}

/// A P-way collective communicator. Clone one handle per participant.
#[derive(Clone)]
pub struct Communicator {
    backend: Backend,
    /// This handle's rank (0..P).
    pub rank: usize,
    /// Optional fault-injection script checked at every phase entry
    /// (DESIGN.md §11). Shared across the group so one-shot specs fire
    /// exactly once pool-wide.
    fault: Option<Arc<FaultPlan>>,
    /// Per-handle 0-based phase counter — the `step` coordinate a
    /// [`FaultPlan`] spec addresses at this injection site.
    phase_no: Cell<usize>,
}

/// Index range `[lo, hi)` of the chunk rank `rank` reduces (remainder
/// spread over the leading ranks; empty for trailing ranks when P > len).
fn chunk_range(len: usize, p: usize, rank: usize) -> (usize, usize) {
    let base = len / p;
    let rem = len % p;
    let lo = rank * base + rank.min(rem);
    (lo, lo + base + usize::from(rank < rem))
}

/// Poison-tolerant lock: the guarded state is plain counters/buffers whose
/// invariants survive a panicking holder, and recovering here is what keeps
/// one rank's panic from cascading `unwrap` panics through every survivor.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Communicator {
    /// Create handles for all P ranks.
    pub fn create(p: usize) -> Vec<Communicator> {
        Communicator::create_with_faults(p, None)
    }

    /// Create handles for all P ranks with an optional fault-injection
    /// plan attached to every handle (checked at each collective phase).
    pub fn create_with_faults(p: usize, fault: Option<Arc<FaultPlan>>) -> Vec<Communicator> {
        assert!(p >= 1);
        let shared = Arc::new(Shared {
            p,
            ctl: Mutex::new(Ctl {
                arrived: 0,
                generation: 0,
                aborted: None,
                bytes_total: 0,
                ops_total: 0,
            }),
            cv: Condvar::new(),
            slots: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
            reduced: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
        });
        (0..p)
            .map(|rank| Communicator {
                backend: Backend::Local(shared.clone()),
                rank,
                fault: fault.clone(),
                phase_no: Cell::new(0),
            })
            .collect()
    }

    /// Create the handle a separate worker *process* uses: collectives
    /// round-trip through the coordinator's hub over the rank transport
    /// instead of shared memory (DESIGN.md §12).
    pub(crate) fn remote(
        rank: usize,
        p: usize,
        io: Arc<RemoteIo>,
        fault: Option<Arc<FaultPlan>>,
    ) -> Communicator {
        Communicator {
            backend: Backend::Remote(Arc::new(RemoteComm::new(io, rank, p))),
            rank,
            fault,
            phase_no: Cell::new(0),
        }
    }

    /// Number of participating ranks P.
    pub fn p(&self) -> usize {
        match &self.backend {
            Backend::Local(shared) => shared.p,
            Backend::Remote(rc) => rc.p(),
        }
    }

    /// (total bytes sent+received across ranks, number of collectives).
    pub fn traffic(&self) -> (u64, u64) {
        match &self.backend {
            Backend::Local(shared) => {
                let s = lock(&shared.ctl);
                (s.bytes_total, s.ops_total)
            }
            Backend::Remote(rc) => rc.traffic(),
        }
    }

    /// Mark the group failed: wakes every waiter, and every in-flight or
    /// subsequent collective on any handle returns a [`CommError`] carrying
    /// this rank and reason. The first abort wins; later ones are no-ops.
    /// On the remote backend the abort is also fanned to every peer
    /// through the coordinator's hub.
    pub fn abort(&self, reason: impl Into<String>) {
        match &self.backend {
            Backend::Local(shared) => {
                let mut s = lock(&shared.ctl);
                if s.aborted.is_none() {
                    s.aborted = Some((self.rank, reason.into()));
                }
                shared.cv.notify_all();
            }
            Backend::Remote(rc) => rc.abort(&reason.into()),
        }
    }

    /// Make this handle's group fresh again after a failure. Remote
    /// handles clear their sticky abort and zero their counters (the
    /// coordinator resets its hub in the same breath); local handles are
    /// a no-op — the pool replaces them wholesale via `Req::NewComm`.
    pub(crate) fn reset(&self) {
        if let Backend::Remote(rc) = &self.backend {
            rc.reset();
            self.phase_no.set(0);
        }
    }

    /// Act out a scripted fault for this (rank, phase, op) coordinate, if
    /// any. `Err` and `Panic` both abort the group first so survivors get
    /// a contextful [`CommError`] naming this rank; `Panic` then unwinds
    /// (the worker thread dies and the pool's supervisor replaces it),
    /// while `Slow` just stalls this rank for the scripted duration.
    fn maybe_inject(&self, op: &'static str) {
        let Some(plan) = &self.fault else { return };
        let step = self.phase_no.get();
        self.phase_no.set(step + 1);
        match plan.fire(self.rank, step, Some(op)) {
            None => {}
            Some(FaultKind::Slow(d)) => std::thread::sleep(d),
            Some(FaultKind::Err) => {
                self.abort(format!("injected fault at {op} (rank {}, phase {step})", self.rank));
            }
            Some(FaultKind::Panic) => {
                let msg =
                    format!("injected panic at {op} (rank {}, phase {step})", self.rank);
                self.abort(msg.clone());
                panic!("{msg}");
            }
            // Transport kinds fire at the frame send site, never here.
            Some(FaultKind::Drop | FaultKind::Delay(_)) => unreachable!(),
        }
    }

    /// One barrier phase: account traffic, arrive, and either release the
    /// group (last arriver advances the generation) or wait. Returns an
    /// error immediately if the group was aborted before or during the
    /// wait.
    fn phase(
        &self,
        shared: &Shared,
        op: &'static str,
        bytes: u64,
        count_op: bool,
    ) -> CommResult<()> {
        self.maybe_inject(op);
        let mut s = lock(&shared.ctl);
        if let Some((rank, reason)) = &s.aborted {
            return Err(CommError { rank: *rank, reason: reason.clone(), op });
        }
        let gen = s.generation;
        s.bytes_total += bytes;
        s.arrived += 1;
        if s.arrived == shared.p {
            s.arrived = 0;
            s.generation += 1;
            if count_op {
                s.ops_total += 1;
            }
            shared.cv.notify_all();
        } else {
            while s.generation == gen && s.aborted.is_none() {
                s = shared.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
            }
            if let Some((rank, reason)) = &s.aborted {
                return Err(CommError { rank: *rank, reason: reason.clone(), op });
            }
        }
        Ok(())
    }

    /// One remote phase entry: run the fault script at the same
    /// (rank, phase, op) coordinates the local backend uses, then
    /// surface the sticky abort if the group already failed. Keeping
    /// the phase grid identical across backends is what lets one
    /// `FaultPlan` address either transport.
    fn remote_guard(&self, rc: &RemoteComm, op: &'static str) -> CommResult<()> {
        self.maybe_inject(op);
        if let Some((rank, reason)) = rc.aborted() {
            return Err(CommError { rank, reason, op });
        }
        Ok(())
    }

    /// Barrier: returns once all P ranks have arrived (or errs on abort).
    pub fn barrier(&self) -> CommResult<()> {
        match &self.backend {
            Backend::Local(shared) => self.phase(shared, "barrier", 0, false),
            Backend::Remote(rc) => {
                self.remote_guard(rc, "barrier")?;
                rc.roundtrip(CollOp::Barrier, Vec::new())
                    .map_err(|(rank, reason)| CommError { rank, reason, op: "barrier" })?;
                Ok(())
            }
        }
    }

    /// All-reduce (sum) in place: after return, `buf` on every rank holds
    /// the element-wise sum over ranks (Alg. 2 line 12 / Alg. 3 line 5).
    ///
    /// Deterministic and chunked: every rank deposits into its own slot,
    /// then reduces its 1/P chunk across the slots *in rank order* — the
    /// same left-fold the lockstep engine's host `add_assign` performs —
    /// while the other ranks reduce their chunks concurrently.
    pub fn all_reduce_sum(&self, buf: &mut [f32]) -> CommResult<()> {
        let shared = match &self.backend {
            Backend::Local(shared) => shared,
            Backend::Remote(rc) => {
                self.remote_guard(rc, "all_reduce(deposit)")?;
                let out = rc.roundtrip(CollOp::AllReduce, buf.to_vec()).map_err(
                    |(rank, reason)| CommError { rank, reason, op: "all_reduce(deposit)" },
                )?;
                rc.add_traffic(4 * buf.len() as u64 * rc.p() as u64, true);
                self.remote_guard(rc, "all_reduce(reduce)")?;
                assert_eq!(out.len(), buf.len(), "all_reduce length mismatch across ranks");
                buf.copy_from_slice(&out);
                return self.remote_guard(rc, "all_reduce(consume)");
            }
        };
        let p = shared.p;
        let len = buf.len();
        {
            let mut slot = lock(&shared.slots[self.rank]);
            slot.clear();
            slot.extend_from_slice(buf);
        }
        self.phase(shared, "all_reduce(deposit)", 4 * len as u64, true)?;
        let (lo, hi) = chunk_range(len, p, self.rank);
        {
            let mut out = lock(&shared.reduced[self.rank]);
            out.clear();
            out.resize(hi - lo, 0.0);
            for r in 0..p {
                let slot = lock(&shared.slots[r]);
                assert_eq!(slot.len(), len, "all_reduce length mismatch across ranks");
                if r == 0 {
                    out.copy_from_slice(&slot[lo..hi]);
                } else {
                    for (o, &x) in out.iter_mut().zip(&slot[lo..hi]) {
                        *o += x;
                    }
                }
            }
        }
        self.phase(shared, "all_reduce(reduce)", 0, false)?;
        for r in 0..p {
            let (rlo, rhi) = chunk_range(len, p, r);
            let red = lock(&shared.reduced[r]);
            buf[rlo..rhi].copy_from_slice(&red);
        }
        // Final barrier so no rank re-deposits before everyone copied out.
        self.phase(shared, "all_reduce(consume)", 0, false)
    }

    /// All-gather: each rank contributes `part`; returns the concatenation
    /// ordered by rank (Alg. 4 line 6).
    pub fn all_gather(&self, part: &[f32]) -> CommResult<Vec<f32>> {
        let shared = match &self.backend {
            Backend::Local(shared) => shared,
            Backend::Remote(rc) => {
                self.remote_guard(rc, "all_gather(deposit)")?;
                let out = rc.roundtrip(CollOp::AllGather, part.to_vec()).map_err(
                    |(rank, reason)| CommError { rank, reason, op: "all_gather(deposit)" },
                )?;
                rc.add_traffic(4 * out.len() as u64, true);
                self.remote_guard(rc, "all_gather(consume)")?;
                return Ok(out);
            }
        };
        {
            let mut slot = lock(&shared.slots[self.rank]);
            slot.clear();
            slot.extend_from_slice(part);
        }
        self.phase(shared, "all_gather(deposit)", 4 * part.len() as u64, true)?;
        let mut out = Vec::new();
        for r in 0..shared.p {
            out.extend_from_slice(&lock(&shared.slots[r]));
        }
        // Ensure all ranks consumed before slots are overwritten.
        self.phase(shared, "all_gather(consume)", 0, false)?;
        Ok(out)
    }

    /// Broadcast from rank 0.
    pub fn broadcast(&self, buf: &mut Vec<f32>) -> CommResult<()> {
        let shared = match &self.backend {
            Backend::Local(shared) => shared,
            Backend::Remote(rc) => {
                self.remote_guard(rc, "broadcast(deposit)")?;
                let payload = if self.rank == 0 { buf.clone() } else { Vec::new() };
                let out = rc.roundtrip(CollOp::Broadcast, payload).map_err(
                    |(rank, reason)| CommError { rank, reason, op: "broadcast(deposit)" },
                )?;
                rc.add_traffic(4 * out.len() as u64, true);
                if self.rank != 0 {
                    buf.clear();
                    buf.extend_from_slice(&out);
                }
                return self.remote_guard(rc, "broadcast(consume)");
            }
        };
        let bytes = if self.rank == 0 {
            let mut slot = lock(&shared.slots[0]);
            slot.clear();
            slot.extend_from_slice(buf);
            4 * buf.len() as u64
        } else {
            0
        };
        self.phase(shared, "broadcast(deposit)", bytes, true)?;
        if self.rank != 0 {
            let slot = lock(&shared.slots[0]);
            buf.clear();
            buf.extend_from_slice(&slot);
        }
        self.phase(shared, "broadcast(consume)", 0, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ranks<F: Fn(Communicator) + Send + Sync + Clone + 'static>(p: usize, f: F) {
        let comms = Communicator::create(p);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                let f = f.clone();
                std::thread::spawn(move || f(c))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn all_reduce_sums() {
        run_ranks(4, |c| {
            let mut buf = vec![c.rank as f32, 1.0, -(c.rank as f32)];
            c.all_reduce_sum(&mut buf).unwrap();
            assert_eq!(buf, vec![6.0, 4.0, -6.0]);
        });
    }

    #[test]
    fn repeated_all_reduce_no_bleed() {
        run_ranks(3, |c| {
            for round in 0..20 {
                let mut buf = vec![(c.rank + round) as f32];
                c.all_reduce_sum(&mut buf).unwrap();
                assert_eq!(buf[0], (3 * round + 3) as f32, "round {round}");
            }
        });
    }

    #[test]
    fn all_reduce_is_rank_order_deterministic() {
        // The chunked reduction must reproduce the sequential rank-order
        // left-fold bitwise — the property that pins rank-parallel scores
        // to the lockstep engine's host reductions.
        let p = 3usize;
        let len = 1001usize; // not divisible by p: exercises chunk remainders
        let val = |rank: usize, i: usize| ((rank * 31 + i * 7) % 97) as f32 * 0.034_217;
        let mut want = vec![0.0f32; len];
        for (i, w) in want.iter_mut().enumerate() {
            *w = val(0, i);
            for r in 1..p {
                *w += val(r, i);
            }
        }
        run_ranks(p, move |c| {
            let mut buf: Vec<f32> = (0..len).map(|i| val(c.rank, i)).collect();
            c.all_reduce_sum(&mut buf).unwrap();
            for i in 0..len {
                assert_eq!(
                    buf[i].to_bits(),
                    want[i].to_bits(),
                    "element {i} not bitwise rank-order deterministic"
                );
            }
        });
    }

    #[test]
    fn all_reduce_shorter_than_p() {
        // len < P leaves trailing ranks with empty chunks.
        run_ranks(4, |c| {
            let mut buf = vec![1.0f32, 2.0];
            c.all_reduce_sum(&mut buf).unwrap();
            assert_eq!(buf, vec![4.0, 8.0]);
        });
    }

    #[test]
    fn all_gather_orders_by_rank() {
        run_ranks(3, |c| {
            let part = vec![c.rank as f32 * 10.0, c.rank as f32 * 10.0 + 1.0];
            let out = c.all_gather(&part).unwrap();
            assert_eq!(out, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        });
    }

    #[test]
    fn broadcast_from_root() {
        run_ranks(4, |c| {
            let mut buf = if c.rank == 0 { vec![3.5, -1.0] } else { vec![0.0; 2] };
            c.broadcast(&mut buf).unwrap();
            assert_eq!(buf, vec![3.5, -1.0]);
        });
    }

    #[test]
    fn single_rank_degenerates() {
        let comms = Communicator::create(1);
        let c = &comms[0];
        let mut buf = vec![2.0];
        c.all_reduce_sum(&mut buf).unwrap();
        assert_eq!(buf, vec![2.0]);
        assert_eq!(c.all_gather(&[1.0, 2.0]).unwrap(), vec![1.0, 2.0]);
        c.barrier().unwrap();
    }

    #[test]
    fn traffic_accounting() {
        run_ranks(2, |c| {
            let mut buf = vec![0.0; 8];
            c.all_reduce_sum(&mut buf).unwrap();
            let _ = c.all_gather(&buf[..4]).unwrap();
        });
        // Recreate to read counters deterministically on one handle.
        let comms = Communicator::create(2);
        let c0 = comms[0].clone();
        let c1 = comms[1].clone();
        let t = std::thread::spawn(move || {
            let mut b = vec![1.0f32; 8];
            c1.all_reduce_sum(&mut b).unwrap();
        });
        let mut b = vec![1.0f32; 8];
        c0.all_reduce_sum(&mut b).unwrap();
        t.join().unwrap();
        let (bytes, ops) = c0.traffic();
        assert_eq!(ops, 1);
        assert_eq!(bytes, 2 * 8 * 4);
    }

    #[test]
    fn interleaved_mixed_collectives() {
        run_ranks(4, |c| {
            for round in 0..10 {
                c.barrier().unwrap();
                let mut buf = vec![1.0f32; 5];
                c.all_reduce_sum(&mut buf).unwrap();
                assert!(buf.iter().all(|&x| x == 4.0));
                let g = c.all_gather(&[c.rank as f32]).unwrap();
                assert_eq!(g, vec![0.0, 1.0, 2.0, 3.0], "round {round}");
                let mut b = vec![round as f32];
                c.broadcast(&mut b).unwrap();
                assert_eq!(b[0], round as f32);
            }
        });
    }

    #[test]
    fn abort_wakes_waiters_and_fails_future_ops() {
        // The hang-on-failure regression (ISSUE 5): a rank that dies
        // mid-collective must not leave the survivors blocked forever.
        for p in [2usize, 4] {
            let comms = Communicator::create(p);
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    std::thread::spawn(move || {
                        if c.rank == 1 {
                            c.abort("device exploded");
                            return;
                        }
                        let mut buf = vec![1.0f32; 64];
                        // Whether the abort lands before we arrive or while
                        // we wait, the collective must return, not hang.
                        let err = c.all_reduce_sum(&mut buf).unwrap_err();
                        assert_eq!(err.rank, 1, "P={p}: wrong aborting rank");
                        assert!(err.reason.contains("device exploded"), "P={p}: {err}");
                        assert!(err.to_string().contains("rank 1"), "P={p}: {err}");
                        // Every subsequent collective fails contextfully too.
                        assert!(c.barrier().is_err(), "P={p}");
                        assert!(c.all_gather(&[1.0]).is_err(), "P={p}");
                        let mut b = vec![0.0f32; 2];
                        assert!(c.broadcast(&mut b).is_err(), "P={p}");
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
    }

    #[test]
    fn first_abort_wins() {
        let comms = Communicator::create(2);
        comms[0].abort("first");
        comms[1].abort("second");
        let err = comms[0].barrier().unwrap_err();
        assert_eq!(err.rank, 0);
        assert_eq!(err.reason, "first");
    }

    /// Every collective phase op name, in call order within the mixed
    /// sequence the injection test drives.
    const PHASE_OPS: [&str; 8] = [
        "barrier",
        "all_reduce(deposit)",
        "all_reduce(reduce)",
        "all_reduce(consume)",
        "all_gather(deposit)",
        "all_gather(consume)",
        "broadcast(deposit)",
        "broadcast(consume)",
    ];

    fn mixed_sequence(c: &Communicator) -> CommResult<()> {
        c.barrier()?;
        let mut buf = vec![1.0f32; 9];
        c.all_reduce_sum(&mut buf)?;
        let _ = c.all_gather(&[c.rank as f32])?;
        let mut b = vec![0.5f32; 2];
        c.broadcast(&mut b)?;
        Ok(())
    }

    #[test]
    fn injected_fault_at_every_collective_phase_is_contextful() {
        // Satellite of ISSUE 7: a scripted abort during deposit / reduce /
        // gather / barrier / broadcast at P∈{2,4} must surface a
        // contextful CommError naming the injected rank on EVERY
        // participant, and a fresh group must recover.
        use crate::collective::fault::FaultPlan;
        for p in [2usize, 4] {
            for inj in PHASE_OPS {
                let plan =
                    Arc::new(FaultPlan::parse(&format!("rank=1,kind=err,op={inj}")).unwrap());
                let comms = Communicator::create_with_faults(p, Some(plan));
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| std::thread::spawn(move || mixed_sequence(&c).err()))
                    .collect();
                for h in handles {
                    let err = h
                        .join()
                        .unwrap()
                        .unwrap_or_else(|| panic!("P={p} op={inj}: rank saw no error"));
                    assert_eq!(err.rank, 1, "P={p} op={inj}: wrong aborting rank: {err}");
                    assert!(
                        err.reason.contains(&format!("injected fault at {inj}")),
                        "P={p} op={inj}: reason lacks injection site: {err}"
                    );
                }
                // Recovery path: the failed group is permanently failed;
                // a fresh group (what RankPool::ensure_live creates) runs
                // the same sequence clean.
                run_ranks(p, |c| mixed_sequence(&c).unwrap());
            }
        }
    }

    #[test]
    fn injected_slow_fault_is_latency_only() {
        use crate::collective::fault::FaultPlan;
        let plan = Arc::new(FaultPlan::parse("rank=0,kind=slow,ms=1,op=barrier").unwrap());
        let comms = Communicator::create_with_faults(2, Some(plan));
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    c.barrier().unwrap();
                    let mut buf = vec![c.rank as f32; 4];
                    c.all_reduce_sum(&mut buf).unwrap();
                    assert_eq!(buf, vec![1.0; 4]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn injected_panic_aborts_the_group_before_unwinding() {
        // A panic-kind comm fault must mark the group aborted first so
        // survivors get a CommError instead of hanging on the condvar.
        use crate::collective::fault::FaultPlan;
        let plan = Arc::new(FaultPlan::parse("rank=1,kind=panic,op=all_reduce(deposit)").unwrap());
        let comms = Communicator::create_with_faults(2, Some(plan));
        let handles: Vec<_> = comms
            .into_iter()
            .map(|c| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; 8];
                    c.all_reduce_sum(&mut buf)
                })
            })
            .collect();
        let survivor = handles.into_iter().map(|h| h.join()).collect::<Vec<_>>();
        // Rank 1's thread panicked; rank 0 joined clean with a CommError.
        assert!(survivor[1].is_err(), "injected panic should unwind rank 1");
        let err = survivor[0].as_ref().unwrap().as_ref().unwrap_err();
        assert_eq!(err.rank, 1);
        assert!(err.reason.contains("injected panic"), "{err}");
    }

    #[test]
    fn chunk_ranges_partition_the_buffer() {
        for (len, p) in [(10usize, 3usize), (3, 4), (0, 2), (8, 1), (7, 7)] {
            let mut covered = 0usize;
            for r in 0..p {
                let (lo, hi) = chunk_range(len, p, r);
                assert_eq!(lo, covered, "len={len} p={p} rank={r}");
                covered = hi;
            }
            assert_eq!(covered, len, "len={len} p={p}");
        }
    }
}
