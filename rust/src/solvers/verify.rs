//! First-class solution feasibility checkers (ISSUE 8 satellite): the
//! canonical truth the quality harness, the equivalence tests, and the
//! environment validators all share, so "bit-exact but infeasible" can
//! never pass anywhere. All checkers stream the CSR directly — no
//! `Graph::edges()` materialization — and stay allocation-free at 30M
//! edges.

use crate::env::Scenario;
use crate::graph::Graph;

/// Every edge has a selected endpoint. `sol[v]` marks selection and must
/// cover all node ids (`sol.len() >= g.n`).
pub fn is_vertex_cover(g: &Graph, sol: &[bool]) -> bool {
    (0..g.n).all(|u| sol[u] || g.neighbors(u).iter().all(|&v| sol[v as usize]))
}

/// No edge has both endpoints selected.
pub fn is_independent_set(g: &Graph, sol: &[bool]) -> bool {
    (0..g.n).all(|u| !sol[u] || g.neighbors(u).iter().all(|&v| !sol[v as usize]))
}

/// Exact cut weight of a side assignment (each undirected edge counted
/// once).
pub fn cut_value(g: &Graph, side: &[bool]) -> i64 {
    let mut cut = 0i64;
    for u in 0..g.n {
        for &v in g.neighbors(u) {
            if (u as u32) < v && side[u] != side[v as usize] {
                cut += 1;
            }
        }
    }
    cut
}

/// Scenario dispatch: is `sol` a feasible solution for `scenario` on `g`?
/// A short mask is infeasible outright; any full-length side assignment
/// is a feasible cut, so MaxCut only checks coverage.
pub fn feasible(scenario: Scenario, g: &Graph, sol: &[bool]) -> bool {
    if sol.len() < g.n {
        return false;
    }
    match scenario {
        Scenario::Mvc => is_vertex_cover(g, sol),
        Scenario::Mis => is_independent_set(g, sol),
        Scenario::MaxCut => true,
    }
}

/// Expand a sorted node-id solution (the wire format of `JobOutcome` and
/// the serve stream) into a selection mask over `n` nodes. Ids outside
/// [0, n) are ignored — `feasible` on the result then reports exactly
/// what the in-range selection achieves.
pub fn ids_to_mask(n: usize, ids: &[usize]) -> Vec<bool> {
    let mut mask = vec![false; n];
    for &v in ids {
        if v < n {
            mask[v] = true;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::maxcut::MaxCutEnv;
    use crate::env::mis::MisEnv;
    use crate::env::mvc::MvcEnv;
    use crate::graph::generators;
    use crate::util::prop;

    fn path4() -> Graph {
        Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap()
    }

    #[test]
    fn cover_checks() {
        let g = path4();
        assert!(is_vertex_cover(&g, &[false, true, true, false]));
        assert!(is_vertex_cover(&g, &[true, true, true, true]));
        assert!(!is_vertex_cover(&g, &[false, false, true, false])); // 0-1 uncovered
        assert!(!is_vertex_cover(&g, &[true, false, false, true])); // 1-2 uncovered
    }

    #[test]
    fn independence_checks() {
        let g = path4();
        assert!(is_independent_set(&g, &[true, false, true, false]));
        assert!(is_independent_set(&g, &[false; 4]));
        assert!(!is_independent_set(&g, &[true, true, false, false]));
    }

    #[test]
    fn cut_checks() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]).unwrap();
        assert_eq!(cut_value(&g, &[true, false, true, false]), 4);
        assert_eq!(cut_value(&g, &[false; 4]), 0);
        assert_eq!(cut_value(&g, &[true, false, false, false]), 2);
    }

    #[test]
    fn feasible_dispatch_and_short_masks() {
        let g = path4();
        assert!(feasible(Scenario::Mvc, &g, &[false, true, true, false]));
        assert!(!feasible(Scenario::Mvc, &g, &[true, true])); // short mask
        assert!(feasible(Scenario::Mis, &g, &[true, false, true, false]));
        assert!(!feasible(Scenario::Mis, &g, &[true, true, false, false]));
        assert!(feasible(Scenario::MaxCut, &g, &[true, false, false, false]));
    }

    #[test]
    fn ids_round_trip_through_mask() {
        let mask = ids_to_mask(5, &[1, 3, 99]);
        assert_eq!(mask, vec![false, true, false, true, false]);
    }

    #[test]
    fn prop_matches_env_checkers() {
        prop::check(
            "verify-matches-env",
            25,
            |r| {
                let g = generators::erdos_renyi(6 + r.gen_range(40), 0.25, r);
                let mask: Vec<bool> = (0..g.n).map(|_| r.next_f64() < 0.5).collect();
                (g, mask)
            },
            |(g, mask)| {
                is_vertex_cover(g, mask) == MvcEnv::is_vertex_cover(g, mask)
                    && is_independent_set(g, mask) == MisEnv::is_independent_set(g, mask)
                    && cut_value(g, mask) == MaxCutEnv::compute_cut(g, mask)
            },
        );
    }
}
