//! Exact Minimum Vertex Cover via branch-and-bound with kernelization.
//!
//! Plays the role of the paper's IBM-CPLEX reference solver (§6.1): it
//! provides the optimal |MVC| used as the denominator of approximation
//! ratios, with a wall-clock cutoff after which the best-known bound is
//! returned (paper used a 0.5 h cutoff).
//!
//! Techniques: degree-0/1 reductions, maximal-matching lower bound,
//! greedy upper bound, branch on max-degree vertex (take v | take N(v)).

use crate::graph::Graph;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
/// Outcome of the branch-and-bound MVC solver.
pub struct ExactResult {
    /// Best cover found (node mask).
    pub cover: Vec<bool>,
    /// |cover|.
    pub size: usize,
    /// True if proven optimal (no cutoff hit).
    pub optimal: bool,
    /// Branch-and-bound nodes explored.
    pub nodes_explored: usize,
}

struct Solver<'g> {
    g: &'g Graph,
    deadline: Instant,
    best: Vec<bool>,
    best_size: usize,
    nodes: usize,
    timed_out: bool,
}

impl<'g> Solver<'g> {
    /// Maximal-matching lower bound on the residual graph.
    fn lower_bound(&self, alive: &[bool]) -> usize {
        let mut used = vec![false; self.g.n];
        let mut matching = 0;
        for u in 0..self.g.n {
            if !alive[u] || used[u] {
                continue;
            }
            for &v in self.g.neighbors(u) {
                let v = v as usize;
                if alive[v] && !used[v] && v != u {
                    used[u] = true;
                    used[v] = true;
                    matching += 1;
                    break;
                }
            }
        }
        matching
    }

    fn recurse(&mut self, alive: &mut Vec<bool>, chosen: &mut Vec<bool>, size: usize) {
        self.nodes += 1;
        if self.nodes % 4096 == 0 && Instant::now() > self.deadline {
            self.timed_out = true;
        }
        if self.timed_out {
            return;
        }

        // Kernelization: repeatedly apply degree-0 and degree-1 rules.
        let mut forced: Vec<usize> = Vec::new();
        let mut size = size;
        loop {
            let mut changed = false;
            for v in 0..self.g.n {
                if !alive[v] {
                    continue;
                }
                let deg = self
                    .g
                    .neighbors(v)
                    .iter()
                    .filter(|&&u| alive[u as usize])
                    .count();
                if deg == 0 {
                    alive[v] = false; // isolated: never in an optimal cover
                    forced.push(v);
                    changed = true;
                } else if deg == 1 {
                    // Take v's unique neighbor.
                    let u = *self
                        .g
                        .neighbors(v)
                        .iter()
                        .find(|&&u| alive[u as usize])
                        .unwrap() as usize;
                    chosen[u] = true;
                    alive[u] = false;
                    alive[v] = false;
                    forced.push(u);
                    forced.push(v);
                    size += 1;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }

        // Find max-degree branching vertex.
        let mut branch_v = None;
        let mut branch_deg = 0;
        for v in 0..self.g.n {
            if !alive[v] {
                continue;
            }
            let deg = self
                .g
                .neighbors(v)
                .iter()
                .filter(|&&u| alive[u as usize])
                .count();
            if deg > branch_deg {
                branch_deg = deg;
                branch_v = Some(v);
            }
        }

        match branch_v {
            None => {
                // No edges left: complete cover.
                if size < self.best_size {
                    self.best_size = size;
                    self.best = chosen.clone();
                }
            }
            Some(v) => {
                if size + self.lower_bound(alive) < self.best_size {
                    // Branch 1: take v.
                    chosen[v] = true;
                    alive[v] = false;
                    self.recurse(alive, chosen, size + 1);
                    alive[v] = true;
                    chosen[v] = false;

                    // Branch 2: exclude v => take all alive neighbors.
                    let nbrs: Vec<usize> = self
                        .g
                        .neighbors(v)
                        .iter()
                        .map(|&u| u as usize)
                        .filter(|&u| alive[u])
                        .collect();
                    if size + nbrs.len() < self.best_size {
                        alive[v] = false;
                        for &u in &nbrs {
                            chosen[u] = true;
                            alive[u] = false;
                        }
                        self.recurse(alive, chosen, size + nbrs.len());
                        for &u in &nbrs {
                            chosen[u] = false;
                            alive[u] = true;
                        }
                        alive[v] = true;
                    }
                }
            }
        }

        // Undo kernelization.
        for &v in forced.iter().rev() {
            alive[v] = true;
            chosen[v] = false;
        }
    }
}

/// Exact MVC with a time budget. Always returns a *valid* cover (greedy
/// fallback seeds the incumbent), `optimal=false` if the cutoff was hit.
pub fn exact_mvc(g: &Graph, budget: Duration) -> ExactResult {
    // Seed incumbent with the greedy cover (upper bound).
    let greedy = super::greedy::greedy_mvc(g);
    let best_size = greedy.iter().filter(|&&b| b).count();
    let mut solver = Solver {
        g,
        deadline: Instant::now() + budget,
        best: greedy,
        best_size,
        nodes: 0,
        timed_out: false,
    };
    let mut alive = vec![true; g.n];
    let mut chosen = vec![false; g.n];
    solver.recurse(&mut alive, &mut chosen, 0);
    ExactResult {
        cover: solver.best,
        size: solver.best_size,
        optimal: !solver.timed_out,
        nodes_explored: solver.nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::mvc::MvcEnv;
    use crate::graph::generators;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn brute_force_mvc(g: &Graph) -> usize {
        // Only for tiny graphs.
        let n = g.n;
        assert!(n <= 20);
        let edges = g.edges();
        (0..(1u32 << n))
            .filter(|mask| {
                edges
                    .iter()
                    .all(|&(u, v)| mask & (1 << u) != 0 || mask & (1 << v) != 0)
            })
            .map(|mask| mask.count_ones() as usize)
            .min()
            .unwrap()
    }

    #[test]
    fn known_graphs() {
        let tri = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap();
        assert_eq!(exact_mvc(&tri, Duration::from_secs(5)).size, 2);
        let path = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(exact_mvc(&path, Duration::from_secs(5)).size, 2);
        let star = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        assert_eq!(exact_mvc(&star, Duration::from_secs(5)).size, 1);
        let empty = Graph::empty(4);
        assert_eq!(exact_mvc(&empty, Duration::from_secs(5)).size, 0);
    }

    #[test]
    fn matches_brute_force_on_random_graphs() {
        prop::check_msg(
            "exact-vs-bruteforce",
            15,
            |r| generators::erdos_renyi(8 + r.gen_range(8), 0.3, r),
            |g| {
                let got = exact_mvc(g, Duration::from_secs(10));
                let want = brute_force_mvc(g);
                if !got.optimal {
                    return Err("timed out on tiny graph".into());
                }
                if !MvcEnv::is_vertex_cover(g, &got.cover) {
                    return Err("returned non-cover".into());
                }
                if got.size != want {
                    return Err(format!("size {} vs brute {want}", got.size));
                }
                if got.cover.iter().filter(|&&b| b).count() != got.size {
                    return Err("cover mask inconsistent with size".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn solves_paper_scale_training_graphs() {
        // 20-node ER(0.15) graphs (Fig. 6's training size) must solve fast.
        let mut rng = Pcg32::seeded(42);
        for _ in 0..5 {
            let g = generators::erdos_renyi(20, 0.15, &mut rng);
            let r = exact_mvc(&g, Duration::from_secs(5));
            assert!(r.optimal);
            assert!(MvcEnv::is_vertex_cover(&g, &r.cover));
        }
    }

    #[test]
    fn cutoff_returns_valid_incumbent() {
        let mut rng = Pcg32::seeded(1);
        let g = generators::erdos_renyi(300, 0.15, &mut rng);
        let r = exact_mvc(&g, Duration::from_millis(50));
        assert!(MvcEnv::is_vertex_cover(&g, &r.cover));
    }
}
