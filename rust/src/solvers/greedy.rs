//! Greedy heuristics: max-degree greedy MVC (repeatedly take the node
//! covering the most uncovered edges — the classic hand-crafted heuristic
//! the RL agent is compared against, and the upper-bound seed for the
//! exact solver) and min-degree greedy MIS.

use crate::graph::Graph;

/// Greedy vertex cover; returns the selected-node mask.
pub fn greedy_mvc(g: &Graph) -> Vec<bool> {
    let mut chosen = vec![false; g.n];
    let mut uncovered_deg: Vec<usize> = (0..g.n).map(|v| g.degree(v)).collect();
    let mut remaining = g.m;
    // Simple binary-heap of (deg, node) with lazy invalidation.
    let mut heap: std::collections::BinaryHeap<(usize, usize)> =
        (0..g.n).map(|v| (uncovered_deg[v], v)).collect();
    while remaining > 0 {
        let (d, v) = heap.pop().expect("edges remain but heap empty");
        if chosen[v] || d != uncovered_deg[v] || d == 0 {
            continue; // stale entry
        }
        chosen[v] = true;
        for &u in g.neighbors(v) {
            let u = u as usize;
            if !chosen[u] && uncovered_deg[u] > 0 {
                uncovered_deg[u] -= 1;
                remaining -= 1;
                heap.push((uncovered_deg[u], u));
            }
        }
        uncovered_deg[v] = 0;
    }
    chosen
}

/// Min-degree greedy MIS: repeatedly select a surviving node of minimum
/// residual degree and remove its closed neighborhood. The standard
/// greedy baseline for independent set; the result is maximal by
/// construction.
pub fn greedy_mis(g: &Graph) -> Vec<bool> {
    use std::cmp::Reverse;
    let mut in_set = vec![false; g.n];
    let mut removed = vec![false; g.n];
    let mut live_deg: Vec<usize> = (0..g.n).map(|v| g.degree(v)).collect();
    // Min-heap of (residual degree, node) with lazy invalidation.
    let mut heap: std::collections::BinaryHeap<Reverse<(usize, usize)>> =
        (0..g.n).map(|v| Reverse((live_deg[v], v))).collect();
    while let Some(Reverse((d, v))) = heap.pop() {
        if removed[v] || d != live_deg[v] {
            continue; // stale entry
        }
        in_set[v] = true;
        removed[v] = true;
        for &u in g.neighbors(v) {
            let u = u as usize;
            if removed[u] {
                continue;
            }
            removed[u] = true;
            for &w in g.neighbors(u) {
                let w = w as usize;
                if !removed[w] {
                    live_deg[w] -= 1;
                    heap.push(Reverse((live_deg[w], w)));
                }
            }
        }
    }
    in_set
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::mvc::MvcEnv;
    use crate::graph::generators;
    use crate::util::prop;

    #[test]
    fn star_takes_center() {
        let g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let c = greedy_mvc(&g);
        assert_eq!(c, vec![true, false, false, false, false]);
    }

    #[test]
    fn empty_graph_takes_nothing() {
        assert!(greedy_mvc(&Graph::empty(5)).iter().all(|&b| !b));
    }

    #[test]
    fn prop_greedy_returns_cover() {
        prop::check(
            "greedy-is-cover",
            30,
            |r| generators::erdos_renyi(5 + r.gen_range(80), 0.2, r),
            |g| MvcEnv::is_vertex_cover(g, &greedy_mvc(g)),
        );
    }

    #[test]
    fn mis_star_takes_leaves() {
        let g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let s = greedy_mis(&g);
        assert_eq!(s, vec![false, true, true, true, true]);
    }

    #[test]
    fn mis_empty_graph_takes_all() {
        assert!(greedy_mis(&Graph::empty(4)).iter().all(|&b| b));
    }

    #[test]
    fn prop_greedy_mis_is_maximal_independent() {
        use crate::solvers::verify;
        prop::check(
            "greedy-mis-maximal",
            30,
            |r| generators::erdos_renyi(5 + r.gen_range(80), 0.2, r),
            |g| {
                let s = greedy_mis(g);
                verify::is_independent_set(g, &s)
                    && (0..g.n)
                        .all(|v| s[v] || g.neighbors(v).iter().any(|&u| s[u as usize]))
            },
        );
    }
}
