//! Greedy + 1-flip local search for MaxCut — the classical baseline for the
//! MaxCut extension environment.

use crate::graph::Graph;
use crate::util::rng::Pcg32;

/// Randomized greedy construction followed by first-improvement 1-flip
/// local search. Returns (cut mask, cut value).
pub fn local_search_maxcut(g: &Graph, rng: &mut Pcg32, max_rounds: usize) -> (Vec<bool>, i64) {
    let mut side = vec![false; g.n];
    // Random initial assignment.
    for s in side.iter_mut() {
        *s = rng.next_f32() < 0.5;
    }
    let gain = |side: &[bool], v: usize| -> i64 {
        let mut d = 0i64;
        for &u in g.neighbors(v) {
            if side[u as usize] == side[v] {
                d += 1; // flipping v makes these edges cut
            } else {
                d -= 1;
            }
        }
        d
    };
    for _ in 0..max_rounds {
        let mut improved = false;
        for v in 0..g.n {
            if gain(&side, v) > 0 {
                side[v] = !side[v];
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    let value = crate::env::maxcut::MaxCutEnv::compute_cut(g, &side);
    (side, value)
}

/// Deterministic greedy MaxCut: sweep nodes once in id order, placing each
/// on the side that cuts more of its edges to already-placed neighbors.
/// Every edge is cut or not at its later endpoint's majority choice, so
/// the result is a guaranteed (1/2)-approximation with no randomness —
/// the reproducible second MaxCut baseline for the quality harness.
pub fn greedy_maxcut(g: &Graph) -> (Vec<bool>, i64) {
    let mut side = vec![false; g.n];
    for v in 0..g.n {
        let mut cut_if_true = 0i64;
        let mut cut_if_false = 0i64;
        for &u in g.neighbors(v) {
            let u = u as usize;
            if u < v {
                if side[u] {
                    cut_if_false += 1;
                } else {
                    cut_if_true += 1;
                }
            }
        }
        side[v] = cut_if_true >= cut_if_false;
    }
    let value = crate::solvers::verify::cut_value(g, &side);
    (side, value)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn local_optimum_has_no_improving_flip() {
        let mut rng = Pcg32::seeded(2);
        let g = generators::erdos_renyi(40, 0.2, &mut rng);
        let (side, val) = local_search_maxcut(&g, &mut rng, 100);
        for v in 0..g.n {
            let mut flipped = side.clone();
            flipped[v] = !flipped[v];
            let nv = crate::env::maxcut::MaxCutEnv::compute_cut(&g, &flipped);
            assert!(nv <= val, "flip of {v} improves {val} -> {nv}");
        }
    }

    #[test]
    fn cut_at_least_half_edges() {
        // Local optimum of 1-flip is a (1/2)-approximation.
        let mut rng = Pcg32::seeded(3);
        let g = generators::erdos_renyi(60, 0.15, &mut rng);
        let (_, val) = local_search_maxcut(&g, &mut rng, 1000);
        assert!(val * 2 >= g.m as i64, "cut {val} vs m {}", g.m);
    }

    #[test]
    fn greedy_cut_at_least_half_edges() {
        use crate::util::prop;
        prop::check(
            "greedy-maxcut-half",
            30,
            |r| generators::erdos_renyi(6 + r.gen_range(60), 0.05 + r.next_f64() * 0.3, r),
            |g| {
                let (side, val) = greedy_maxcut(g);
                val == crate::solvers::verify::cut_value(g, &side)
                    && val * 2 >= g.m as i64
            },
        );
    }

    #[test]
    fn greedy_cut_deterministic() {
        let g = generators::erdos_renyi(40, 0.2, &mut Pcg32::seeded(8));
        assert_eq!(greedy_maxcut(&g), greedy_maxcut(&g));
    }
}
