//! Greedy + 1-flip local search for MaxCut — the classical baseline for the
//! MaxCut extension environment.

use crate::graph::Graph;
use crate::util::rng::Pcg32;

/// Randomized greedy construction followed by first-improvement 1-flip
/// local search. Returns (cut mask, cut value).
pub fn local_search_maxcut(g: &Graph, rng: &mut Pcg32, max_rounds: usize) -> (Vec<bool>, i64) {
    let mut side = vec![false; g.n];
    // Random initial assignment.
    for s in side.iter_mut() {
        *s = rng.next_f32() < 0.5;
    }
    let gain = |side: &[bool], v: usize| -> i64 {
        let mut d = 0i64;
        for &u in g.neighbors(v) {
            if side[u as usize] == side[v] {
                d += 1; // flipping v makes these edges cut
            } else {
                d -= 1;
            }
        }
        d
    };
    for _ in 0..max_rounds {
        let mut improved = false;
        for v in 0..g.n {
            if gain(&side, v) > 0 {
                side[v] = !side[v];
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    let value = crate::env::maxcut::MaxCutEnv::compute_cut(g, &side);
    (side, value)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn local_optimum_has_no_improving_flip() {
        let mut rng = Pcg32::seeded(2);
        let g = generators::erdos_renyi(40, 0.2, &mut rng);
        let (side, val) = local_search_maxcut(&g, &mut rng, 100);
        for v in 0..g.n {
            let mut flipped = side.clone();
            flipped[v] = !flipped[v];
            let nv = crate::env::maxcut::MaxCutEnv::compute_cut(&g, &flipped);
            assert!(nv <= val, "flip of {v} improves {val} -> {nv}");
        }
    }

    #[test]
    fn cut_at_least_half_edges() {
        // Local optimum of 1-flip is a (1/2)-approximation.
        let mut rng = Pcg32::seeded(3);
        let g = generators::erdos_renyi(60, 0.15, &mut rng);
        let (_, val) = local_search_maxcut(&g, &mut rng, 1000);
        assert!(val * 2 >= g.m as i64, "cut {val} vs m {}", g.m);
    }
}
