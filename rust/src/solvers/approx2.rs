//! 2-approximation for MVC via maximal matching: take both endpoints of a
//! maximal matching. Guaranteed |cover| <= 2·OPT — the approximation
//! baseline from the paper's intro taxonomy (§1).

use crate::graph::Graph;

/// Matching-based 2-approximate vertex cover.
pub fn two_approx_mvc(g: &Graph) -> Vec<bool> {
    let mut chosen = vec![false; g.n];
    for u in 0..g.n {
        if chosen[u] {
            continue;
        }
        for &v in g.neighbors(u) {
            let v = v as usize;
            if !chosen[v] {
                chosen[u] = true;
                chosen[v] = true;
                break;
            }
        }
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::mvc::MvcEnv;
    use crate::graph::generators;
    use crate::solvers::exact::exact_mvc;
    use crate::util::prop;
    use std::time::Duration;

    #[test]
    fn prop_cover_and_ratio_bound() {
        prop::check_msg(
            "2approx-ratio",
            20,
            |r| generators::erdos_renyi(8 + r.gen_range(25), 0.25, r),
            |g| {
                let cover = two_approx_mvc(g);
                if !MvcEnv::is_vertex_cover(g, &cover) {
                    return Err("not a cover".into());
                }
                let size = cover.iter().filter(|&&b| b).count();
                let opt = exact_mvc(g, Duration::from_secs(10));
                if !opt.optimal {
                    return Err("exact timed out".into());
                }
                if opt.size == 0 {
                    if size != 0 {
                        return Err("nonzero cover of empty graph".into());
                    }
                    return Ok(());
                }
                if size > 2 * opt.size {
                    return Err(format!("ratio violated: {size} > 2*{}", opt.size));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn even_cardinality() {
        // Matching-based cover always has even size.
        let mut rng = crate::util::rng::Pcg32::seeded(8);
        for _ in 0..10 {
            let g = generators::erdos_renyi(30, 0.2, &mut rng);
            let c = two_approx_mvc(&g);
            assert_eq!(c.iter().filter(|&&b| b).count() % 2, 0);
        }
    }
}
