//! Classical solvers: the reference-optimum provider (the paper used IBM
//! CPLEX with a 0.5h cutoff; we substitute an exact branch-and-bound, see
//! DESIGN.md §3) plus the approximation/heuristic baselines used to judge
//! solution quality.

/// Exact branch-and-bound MVC (CPLEX stand-in, DESIGN.md §3).
pub mod exact;
/// Greedy heuristics (max-degree MVC, min-degree MIS).
pub mod greedy;
/// Maximal-matching 2-approximation for MVC.
pub mod approx2;
/// Local-search refinement over a feasible cover.
pub mod localsearch;
/// Streaming feasibility checkers (cover / independence / cut value).
pub mod verify;

pub use approx2::two_approx_mvc;
pub use exact::{exact_mvc, ExactResult};
pub use greedy::{greedy_mis, greedy_mvc};
pub use localsearch::{greedy_maxcut, local_search_maxcut};
