//! Analytical performance and memory models (paper §5).
//!
//! Implements Eq. 3–7 (parallel time / efficiency of the embedding and
//! action-evaluation models) and the §5.2 memory-cost model. `bench_analysis`
//! compares the model's scaling predictions with measured step times.

use crate::collective::CostModel;

/// Solution-quality evaluation harness (`oggm eval`).
pub mod quality;

/// Problem/config parameters for the analytical model.
#[derive(Debug, Clone, Copy)]
pub struct ModelConfig {
    /// Mini-batch of graphs B.
    pub b: usize,
    /// Nodes per graph N.
    pub n: usize,
    /// Edge probability ρ.
    pub rho: f64,
    /// Embedding dimension K.
    pub k: usize,
    /// Embedding layers L.
    pub l: usize,
    /// Per-FLOP time of the device (seconds); calibrated from measurement.
    pub sec_per_flop: f64,
    /// Network model (α, β).
    pub net: CostModel,
}

impl ModelConfig {
    /// Eq. 3: parallel embedding-model evaluation time on P devices.
    pub fn t_embed(&self, p: usize) -> f64 {
        let (b, n, k, l, rho) = (
            self.b as f64,
            self.n as f64,
            self.k as f64,
            self.l as f64,
            self.rho,
        );
        let pf = p as f64;
        let compute = (n * n / pf) * (b * k * (rho + l) + b * k * (2.0 + k + 4.0 * l) / n);
        let comm = if p > 1 {
            self.net.alpha * l * pf.log2()
                + self.net.beta * l * b * k * n * 4.0
        } else {
            0.0
        };
        compute * self.sec_per_flop + comm
    }

    /// Eq. 4: sequential embedding time.
    pub fn t_embed_seq(&self) -> f64 {
        self.t_embed(1)
    }

    /// Eq. 5: parallel action-evaluation time on P devices.
    pub fn t_action(&self, p: usize) -> f64 {
        let (b, n, k) = (self.b as f64, self.n as f64, self.k as f64);
        let pf = p as f64;
        let compute = (b * k * n / pf) * (6.0 + k + k * pf / n);
        let comm = if p > 1 {
            self.net.alpha * pf.log2() + self.net.beta * b * k * 4.0
        } else {
            0.0
        };
        compute * self.sec_per_flop + comm
    }

    /// Parallel efficiency E(P) = (T_par(P) / (T_seq / P))^-1.
    pub fn efficiency_embed(&self, p: usize) -> f64 {
        (self.t_embed_seq() / p as f64) / self.t_embed(p)
    }

    /// Parallel efficiency of the action-evaluation phase.
    pub fn efficiency_action(&self, p: usize) -> f64 {
        (self.t_action(1) / p as f64) / self.t_action(p)
    }

    /// One policy evaluation = embedding + action evaluation.
    pub fn t_policy_eval(&self, p: usize) -> f64 {
        self.t_embed(p) + self.t_action(p)
    }
}

/// §5.2 memory model: bytes per device.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// Minibatch size B.
    pub b: usize,
    /// Padded node count N.
    pub n: usize,
    /// Edge probability ρ.
    pub rho: f64,
    /// Replay tuples held.
    pub replay_tuples: usize,
}

impl MemoryModel {
    /// Sparse-COO adjacency bytes per device (paper: 20·N²ρ·B / P).
    pub fn adjacency_coo_bytes(&self, p: usize) -> f64 {
        20.0 * (self.n as f64) * (self.n as f64) * self.rho * self.b as f64 / p as f64
    }

    /// Dense adjacency bytes per device (this repo's compute-path layout:
    /// f32 B×(N/P)×N). The ratio to `adjacency_coo_bytes` quantifies the
    /// densification substitution's overhead (reported in EXPERIMENTS.md).
    pub fn adjacency_dense_bytes(&self, p: usize) -> f64 {
        4.0 * self.b as f64 * (self.n as f64 / p as f64) * self.n as f64
    }

    /// Partial-solution + candidate-set bytes per device (4NB/P each).
    pub fn state_vec_bytes(&self, p: usize) -> f64 {
        4.0 * self.n as f64 * self.b as f64 / p as f64
    }

    /// Replay-buffer bytes per device with the paper's compressed tuples
    /// (8R(N/P + 1)).
    pub fn replay_bytes(&self, p: usize) -> f64 {
        8.0 * self.replay_tuples as f64 * (self.n as f64 / p as f64 + 1.0)
    }

    /// Replay bytes without the §4.4 optimization (storing the full dense
    /// state per tuple) — the ablation baseline.
    pub fn replay_bytes_uncompressed(&self, p: usize) -> f64 {
        self.replay_tuples as f64 * (4.0 * (self.n as f64 / p as f64) * self.n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            b: 1,
            n: 15000,
            rho: 0.15,
            k: 32,
            l: 2,
            sec_per_flop: 1e-10,
            net: CostModel::default(),
        }
    }

    #[test]
    fn efficiency_close_to_one_when_n_large() {
        let c = cfg();
        for p in [2, 4, 6] {
            let e = c.efficiency_embed(p);
            assert!(e > 0.9 && e <= 1.001, "embed efficiency({p}) = {e}");
            let ea = c.efficiency_action(p);
            assert!(ea > 0.9 && ea <= 1.001, "action efficiency({p}) = {ea}");
        }
    }

    #[test]
    fn time_decreases_with_p() {
        let c = cfg();
        assert!(c.t_embed(2) < c.t_embed(1));
        assert!(c.t_embed(6) < c.t_embed(2));
        assert!(c.t_action(6) < c.t_action(1));
    }

    #[test]
    fn efficiency_degrades_for_small_n() {
        let mut c = cfg();
        c.n = 60;
        // With N comparable to P the model must show degraded efficiency.
        assert!(c.efficiency_embed(6) < 0.999);
    }

    #[test]
    fn memory_model_matches_paper_formulas() {
        let m = MemoryModel { b: 1, n: 21000, rho: 0.15, replay_tuples: 50_000 };
        // ~33M edges -> 20 bytes each in COO.
        let edges = 21000.0f64 * 21000.0 * 0.15;
        assert!((m.adjacency_coo_bytes(1) - 20.0 * edges).abs() < 1.0);
        assert!((m.adjacency_coo_bytes(6) - 20.0 * edges / 6.0).abs() < 1.0);
        assert_eq!(m.state_vec_bytes(2), 4.0 * 21000.0 / 2.0);
        assert_eq!(m.replay_bytes(1), 8.0 * 50_000.0 * 21001.0);
        // Compression must beat the dense-per-tuple baseline by orders of magnitude.
        assert!(m.replay_bytes(1) < m.replay_bytes_uncompressed(1) / 100.0);
    }

    #[test]
    fn scaling_shape_matches_fig9() {
        // Fig. 9: 21000-node ER graph, 23.8s -> 3.4s from 1 to 6 GPUs
        // (~7x, superlinear in the paper due to update costs; the model
        // itself must predict between 4x and 8x).
        let mut c = cfg();
        c.n = 21000;
        // Calibrate sec_per_flop so t(1) ~ 23.8s.
        let base = c.t_embed(1) + c.t_action(1);
        c.sec_per_flop *= 23.8 / base;
        let speedup = c.t_policy_eval(1) / c.t_policy_eval(6);
        assert!(speedup > 4.0 && speedup < 8.0, "speedup {speedup}");
    }
}
