//! Solution-quality evaluation harness (the paper's §6 quality study).
//!
//! Scores RL solutions — produced through the same `Service`/`ExecEngine`
//! path as `oggm batch-solve` — against the classical baselines in
//! `solvers/` (exact branch-and-bound, greedy, 2-approximation, local
//! search). Every solution, RL or classical, is re-validated with the
//! streaming checkers in [`crate::solvers::verify`]; the report carries
//! approximation ratios against a per-instance reference (the exact
//! optimum when proven, otherwise the best feasible objective seen),
//! per-solver wall time, and the RL engine's per-step wall time. `oggm
//! eval` is the CLI surface; the JSON schema is validated in CI by
//! `tools/check_eval.py`.

use crate::batch::{run_queue, BatchCfg, Job};
use crate::env::Scenario;
use crate::graph::Graph;
use crate::model::Params;
use crate::runtime::Runtime;
use crate::service::Options;
use crate::solvers::{self, verify};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use anyhow::{bail, ensure, Result};
use std::time::{Duration, Instant};

/// A classical baseline solver the harness can score.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// Exact branch-and-bound MVC (MIS via complement); skipped above
    /// [`EvalCfg::exact_node_cap`] nodes.
    Exact,
    /// Greedy heuristic (max-degree MVC / min-degree MIS / sweep MaxCut).
    Greedy,
    /// Maximal-matching 2-approximation for MVC (MIS via complement).
    Approx2,
    /// Randomized 1-flip local search (MaxCut only).
    LocalSearch,
}

impl Baseline {
    /// Every baseline, in report order.
    pub const ALL: [Baseline; 4] =
        [Baseline::Exact, Baseline::Greedy, Baseline::Approx2, Baseline::LocalSearch];

    /// Canonical lowercase name (the `solver` field of the report).
    pub fn name(self) -> &'static str {
        match self {
            Baseline::Exact => "exact",
            Baseline::Greedy => "greedy",
            Baseline::Approx2 => "approx2",
            Baseline::LocalSearch => "localsearch",
        }
    }

    /// Parse one baseline name.
    pub fn parse(s: &str) -> Result<Baseline> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Ok(Baseline::Exact),
            "greedy" => Ok(Baseline::Greedy),
            "approx2" => Ok(Baseline::Approx2),
            "localsearch" | "local-search" => Ok(Baseline::LocalSearch),
            other => bail!("unknown baseline '{other}' (exact|greedy|approx2|localsearch)"),
        }
    }

    /// Parse a comma-separated baseline list; `"default"` (or empty) means
    /// [`Baseline::defaults`] for the scenario. Inapplicable baselines are
    /// rejected here rather than silently dropped.
    pub fn parse_list(s: &str, scenario: Scenario) -> Result<Vec<Baseline>> {
        if s.is_empty() || s == "default" {
            return Ok(Baseline::defaults(scenario));
        }
        let mut out = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let b = Baseline::parse(tok)?;
            ensure!(
                b.applicable(scenario),
                "baseline '{}' is not applicable to scenario '{}'",
                b.name(),
                scenario.name()
            );
            if !out.contains(&b) {
                out.push(b);
            }
        }
        ensure!(!out.is_empty(), "empty --baselines list");
        Ok(out)
    }

    /// The default baseline set per scenario (≥ 2 each, per EXPERIMENTS.md).
    pub fn defaults(scenario: Scenario) -> Vec<Baseline> {
        match scenario {
            Scenario::Mvc | Scenario::Mis => {
                vec![Baseline::Exact, Baseline::Greedy, Baseline::Approx2]
            }
            Scenario::MaxCut => vec![Baseline::Greedy, Baseline::LocalSearch],
        }
    }

    /// Whether this baseline can solve `scenario` at all.
    pub fn applicable(self, scenario: Scenario) -> bool {
        match self {
            Baseline::Exact | Baseline::Approx2 => {
                matches!(scenario, Scenario::Mvc | Scenario::Mis)
            }
            Baseline::Greedy => true,
            Baseline::LocalSearch => matches!(scenario, Scenario::MaxCut),
        }
    }
}

/// Harness configuration (see `oggm eval`).
#[derive(Debug, Clone)]
pub struct EvalCfg {
    /// The problem every instance is solved as.
    pub scenario: Scenario,
    /// Baselines to score (inapplicable entries are skipped).
    pub baselines: Vec<Baseline>,
    /// Wall-clock cutoff for the exact solver (the paper used 0.5 h).
    pub exact_budget: Duration,
    /// Skip the exact solver above this many nodes (branch-and-bound is
    /// exponential; the cap keeps large-graph runs bounded).
    pub exact_node_cap: usize,
    /// Seed for the randomized local-search baseline.
    pub seed: u64,
    /// Local-search sweep limit.
    pub ls_rounds: usize,
}

impl EvalCfg {
    /// Defaults: scenario's default baselines, 10 s exact budget,
    /// 2000-node exact cap, seed 3, 200 local-search rounds.
    pub fn new(scenario: Scenario) -> EvalCfg {
        EvalCfg {
            scenario,
            baselines: Baseline::defaults(scenario),
            exact_budget: Duration::from_secs(10),
            exact_node_cap: 2000,
            seed: 3,
            ls_rounds: 200,
        }
    }
}

/// A named instance to evaluate.
#[derive(Debug, Clone)]
pub struct Instance {
    /// Report name (file stem or generator spec).
    pub name: String,
    /// The graph.
    pub graph: Graph,
}

/// One solver's scored solution on one instance.
#[derive(Debug, Clone)]
pub struct SolverScore {
    /// Solver name (`rl` or a [`Baseline::name`]).
    pub solver: String,
    /// Scenario objective (|S| for MVC/MIS, cut weight for MaxCut).
    pub objective: f64,
    /// Selected-node count of the solution mask.
    pub size: usize,
    /// Verified by [`verify::feasible`] (never trusted from the solver).
    pub feasible: bool,
    /// True iff this is the exact solver and it proved optimality.
    pub optimal: bool,
    /// Approximation ratio vs the instance reference (≥ 1.0 unless the
    /// reference itself is beaten, which indicates an infeasible
    /// "solution" slipped through — check_eval.py flags both).
    pub ratio: f64,
    /// Wall time spent producing this solution, seconds. For RL this is
    /// the pack wall time divided evenly over the pack's jobs.
    pub wall_s: f64,
    /// RL only: pack wall time per engine step, milliseconds.
    pub per_step_ms: Option<f64>,
    /// RL only: Q-model evaluations consumed.
    pub evaluations: Option<usize>,
}

/// All scores for one instance plus its reference objective.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    /// Instance name.
    pub name: String,
    /// |V|.
    pub nodes: usize,
    /// |E|.
    pub edges: usize,
    /// Reference objective (ratio denominator/numerator per direction).
    pub ref_objective: f64,
    /// Which solver supplied the reference.
    pub ref_solver: String,
    /// True iff the reference is a proven optimum.
    pub ref_optimal: bool,
    /// Per-solver scores, RL first when present.
    pub scores: Vec<SolverScore>,
}

/// The full evaluation report (`to_json` is the `oggm eval --out` schema).
#[derive(Debug, Clone)]
pub struct EvalReport {
    /// The scenario every instance was solved as.
    pub scenario: Scenario,
    /// One entry per instance, input order.
    pub instances: Vec<InstanceReport>,
}

/// Approximation ratio of `obj` against `reference`, oriented so 1.0 is
/// optimal and larger is worse for both directions (MVC minimizes, MIS and
/// MaxCut maximize). Degenerate zero objectives score 1.0 when the
/// reference is also zero (empty graph), infinity otherwise.
pub fn ratio(scenario: Scenario, obj: f64, reference: f64) -> f64 {
    let (num, den) = match scenario {
        Scenario::Mvc => (obj, reference),
        Scenario::MaxCut | Scenario::Mis => (reference, obj),
    };
    if den > 0.0 {
        num / den
    } else if num > 0.0 {
        f64::INFINITY
    } else {
        1.0
    }
}

/// True iff objective `a` beats `b` in the scenario's direction.
fn better(scenario: Scenario, a: f64, b: f64) -> bool {
    match scenario {
        Scenario::Mvc => a < b,
        Scenario::MaxCut | Scenario::Mis => a > b,
    }
}

fn mask_size(mask: &[bool]) -> usize {
    mask.iter().filter(|&&b| b).count()
}

/// Run one classical baseline on `g`. Returns `None` when the baseline is
/// inapplicable to the scenario or the exact solver is over the node cap.
fn run_baseline(b: Baseline, cfg: &EvalCfg, g: &Graph, idx: usize) -> Option<SolverScore> {
    let start = Instant::now();
    let (mask, objective, optimal) = match (b, cfg.scenario) {
        (Baseline::Exact, Scenario::Mvc) => {
            if g.n > cfg.exact_node_cap {
                return None;
            }
            let res = solvers::exact_mvc(g, cfg.exact_budget);
            (res.cover, res.size as f64, res.optimal)
        }
        (Baseline::Exact, Scenario::Mis) => {
            if g.n > cfg.exact_node_cap {
                return None;
            }
            // Complement duality: S is a minimum vertex cover iff V \ S is
            // a maximum independent set, so |MIS| = n - |MVC| and the
            // optimality proof carries over.
            let res = solvers::exact_mvc(g, cfg.exact_budget);
            let set: Vec<bool> = res.cover.iter().map(|&c| !c).collect();
            (set, (g.n - res.size) as f64, res.optimal)
        }
        (Baseline::Greedy, Scenario::Mvc) => {
            let cover = solvers::greedy_mvc(g);
            let size = mask_size(&cover) as f64;
            (cover, size, false)
        }
        (Baseline::Greedy, Scenario::Mis) => {
            let set = solvers::greedy_mis(g);
            let size = mask_size(&set) as f64;
            (set, size, false)
        }
        (Baseline::Greedy, Scenario::MaxCut) => {
            let (side, val) = solvers::greedy_maxcut(g);
            (side, val as f64, false)
        }
        (Baseline::Approx2, Scenario::Mvc) => {
            let cover = solvers::two_approx_mvc(g);
            let size = mask_size(&cover) as f64;
            (cover, size, false)
        }
        (Baseline::Approx2, Scenario::Mis) => {
            // The complement of any vertex cover is an independent set.
            let set: Vec<bool> = solvers::two_approx_mvc(g).iter().map(|&c| !c).collect();
            let size = mask_size(&set) as f64;
            (set, size, false)
        }
        (Baseline::LocalSearch, Scenario::MaxCut) => {
            let mut rng = Pcg32::new(cfg.seed, 100 + idx as u64);
            let (side, val) = solvers::local_search_maxcut(g, &mut rng, cfg.ls_rounds);
            (side, val as f64, false)
        }
        _ => return None,
    };
    let wall_s = start.elapsed().as_secs_f64();
    Some(SolverScore {
        solver: b.name().to_string(),
        objective,
        size: mask_size(&mask),
        feasible: verify::feasible(cfg.scenario, g, &mask),
        optimal,
        ratio: 1.0,
        wall_s,
        per_step_ms: None,
        evaluations: None,
    })
}

/// Evaluate `instances`: solve each with RL through the `Service` path
/// (when a runtime + trained params are supplied) and with the configured
/// classical baselines, re-validate every solution, and score
/// approximation ratios against the per-instance reference.
pub fn evaluate(
    rt: Option<&Runtime>,
    params: Option<&Params>,
    opts: &Options,
    cfg: &EvalCfg,
    instances: &[Instance],
) -> Result<EvalReport> {
    ensure!(!instances.is_empty(), "no instances to evaluate");

    // RL pass first: all instances submitted as one queue so same-bucket
    // graphs share packed forward passes (the engine's whole point).
    let mut rl: Vec<Option<SolverScore>> = vec![None; instances.len()];
    if let (Some(rt), Some(params)) = (rt, params) {
        let jobs: Vec<Job> = instances
            .iter()
            .map(|inst| Job {
                id: inst.name.clone(),
                scenario: cfg.scenario,
                graph: inst.graph.clone(),
            })
            .collect();
        let report = run_queue(rt, &BatchCfg::from(opts), params, &jobs)?;
        ensure!(
            report.outcomes.len() == instances.len(),
            "RL queue returned {} outcomes for {} instances",
            report.outcomes.len(),
            instances.len()
        );
        for out in &report.outcomes {
            let idx = instances
                .iter()
                .position(|inst| inst.name == out.id)
                .ok_or_else(|| anyhow::anyhow!("RL outcome for unknown job '{}'", out.id))?;
            let g = &instances[idx].graph;
            let mask = verify::ids_to_mask(g.n, &out.solution);
            // Re-validate: in-range ids + the scenario's structural check.
            let feasible = out.solution.iter().all(|&v| v < g.n)
                && out.solution_size == mask_size(&mask)
                && verify::feasible(cfg.scenario, g, &mask);
            let pack = report.packs.iter().find(|p| p.pack == out.pack);
            let per_step_ms = pack.and_then(|p| {
                (p.rounds > 0).then(|| p.wall_time * 1000.0 / p.rounds as f64)
            });
            let wall_s = pack
                .map(|p| p.wall_time / (p.jobs.max(1)) as f64)
                .unwrap_or(0.0);
            rl[idx] = Some(SolverScore {
                solver: "rl".to_string(),
                objective: out.objective,
                size: out.solution_size,
                feasible,
                optimal: false,
                ratio: 1.0,
                wall_s,
                per_step_ms,
                evaluations: Some(out.evaluations),
            });
        }
    }

    let baselines: Vec<Baseline> = cfg
        .baselines
        .iter()
        .copied()
        .filter(|b| b.applicable(cfg.scenario))
        .collect();

    let mut reports = Vec::with_capacity(instances.len());
    for (idx, inst) in instances.iter().enumerate() {
        let g = &inst.graph;
        let mut scores: Vec<SolverScore> = Vec::new();
        if let Some(s) = rl[idx].take() {
            scores.push(s);
        }
        for &b in &baselines {
            if let Some(s) = run_baseline(b, cfg, g, idx) {
                scores.push(s);
            }
        }
        ensure!(
            !scores.is_empty(),
            "instance '{}': no solver produced a solution (exact over cap?)",
            inst.name
        );

        // Reference: the proven optimum when the exact solver finished,
        // otherwise the best *feasible* objective any solver achieved —
        // so every feasible ratio is ≥ 1.0 by construction.
        let proven = scores.iter().find(|s| s.optimal && s.feasible);
        let (ref_objective, ref_solver, ref_optimal) = match proven {
            Some(e) => (e.objective, e.solver.clone(), true),
            None => {
                let mut best: Option<&SolverScore> = None;
                for s in scores.iter().filter(|s| s.feasible) {
                    best = match best {
                        Some(b) if !better(cfg.scenario, s.objective, b.objective) => Some(b),
                        _ => Some(s),
                    };
                }
                let best = match best {
                    Some(b) => b,
                    None => bail!(
                        "instance '{}': every solver produced an infeasible solution",
                        inst.name
                    ),
                };
                (best.objective, best.solver.clone(), false)
            }
        };
        for s in scores.iter_mut() {
            s.ratio = ratio(cfg.scenario, s.objective, ref_objective);
        }

        reports.push(InstanceReport {
            name: inst.name.clone(),
            nodes: g.n,
            edges: g.m,
            ref_objective,
            ref_solver,
            ref_optimal,
            scores,
        });
    }
    Ok(EvalReport { scenario: cfg.scenario, instances: reports })
}

impl EvalReport {
    /// Count of solver scores that failed feasibility validation.
    pub fn infeasible_count(&self) -> usize {
        self.instances
            .iter()
            .flat_map(|i| i.scores.iter())
            .filter(|s| !s.feasible)
            .count()
    }

    /// Worst (largest) ratio over every feasible score, 1.0 when empty.
    pub fn worst_ratio(&self) -> f64 {
        self.instances
            .iter()
            .flat_map(|i| i.scores.iter())
            .filter(|s| s.feasible)
            .fold(1.0, |acc, s| acc.max(s.ratio))
    }

    /// Mean ratio of one solver's feasible scores across instances.
    pub fn mean_ratio(&self, solver: &str) -> Option<f64> {
        let ratios: Vec<f64> = self
            .instances
            .iter()
            .flat_map(|i| i.scores.iter())
            .filter(|s| s.solver == solver && s.feasible)
            .map(|s| s.ratio)
            .collect();
        if ratios.is_empty() {
            None
        } else {
            Some(ratios.iter().sum::<f64>() / ratios.len() as f64)
        }
    }

    /// Solver names in first-appearance order across the report.
    pub fn solvers(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for s in self.instances.iter().flat_map(|i| i.scores.iter()) {
            if !out.iter().any(|n| n == &s.solver) {
                out.push(s.solver.clone());
            }
        }
        out
    }

    /// Render the `oggm eval` JSON report (schema checked by
    /// `tools/check_eval.py`).
    pub fn to_json(&self) -> Json {
        let instances: Vec<Json> = self
            .instances
            .iter()
            .map(|r| {
                let scores: Vec<Json> = r
                    .scores
                    .iter()
                    .map(|s| {
                        let mut j = Json::obj()
                            .set("solver", s.solver.as_str())
                            .set("objective", s.objective)
                            .set("size", s.size)
                            .set("feasible", s.feasible)
                            .set("optimal", s.optimal)
                            .set("ratio", s.ratio)
                            .set("wall_s", s.wall_s);
                        if let Some(ms) = s.per_step_ms {
                            j = j.set("per_step_ms", ms);
                        }
                        if let Some(e) = s.evaluations {
                            j = j.set("evaluations", e);
                        }
                        j
                    })
                    .collect();
                Json::obj()
                    .set("name", r.name.as_str())
                    .set("nodes", r.nodes)
                    .set("edges", r.edges)
                    .set(
                        "reference",
                        Json::obj()
                            .set("solver", r.ref_solver.as_str())
                            .set("objective", r.ref_objective)
                            .set("optimal", r.ref_optimal),
                    )
                    .set("scores", Json::Arr(scores))
            })
            .collect();
        let mut solvers_json = Json::obj();
        for name in self.solvers() {
            let infeasible = self
                .instances
                .iter()
                .flat_map(|i| i.scores.iter())
                .filter(|s| s.solver == name && !s.feasible)
                .count();
            let worst = self
                .instances
                .iter()
                .flat_map(|i| i.scores.iter())
                .filter(|s| s.solver == name && s.feasible)
                .fold(f64::NAN, f64::max);
            let mut entry = Json::obj().set("infeasible", infeasible);
            if let Some(mean) = self.mean_ratio(&name) {
                entry = entry.set("mean_ratio", mean);
            }
            if !worst.is_nan() {
                entry = entry.set("worst_ratio", worst);
            }
            solvers_json = solvers_json.set(&name, entry);
        }
        let summary = Json::obj()
            .set("instances", self.instances.len())
            .set("worst_ratio", self.worst_ratio())
            .set("infeasible", self.infeasible_count())
            .set("solvers", solvers_json);
        Json::obj()
            .set("scenario", self.scenario.name())
            .set("instances", Json::Arr(instances))
            .set("summary", summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn opts() -> Options {
        Options::default()
    }

    #[test]
    fn baseline_parse_and_defaults() {
        assert_eq!(Baseline::parse("Greedy").unwrap(), Baseline::Greedy);
        assert!(Baseline::parse("cplex").is_err());
        assert_eq!(
            Baseline::parse_list("default", Scenario::MaxCut).unwrap(),
            vec![Baseline::Greedy, Baseline::LocalSearch]
        );
        assert_eq!(
            Baseline::parse_list("greedy, approx2,greedy", Scenario::Mvc).unwrap(),
            vec![Baseline::Greedy, Baseline::Approx2]
        );
        // localsearch is MaxCut-only: rejected for MVC, not dropped.
        assert!(Baseline::parse_list("localsearch", Scenario::Mvc).is_err());
        for s in Scenario::ALL {
            assert!(Baseline::defaults(s).len() >= 2);
            assert!(Baseline::defaults(s).iter().all(|b| b.applicable(s)));
        }
    }

    #[test]
    fn ratio_orientation() {
        // MVC minimizes: worse (larger) cover → ratio > 1.
        assert_eq!(ratio(Scenario::Mvc, 12.0, 10.0), 1.2);
        // MIS/MaxCut maximize: worse (smaller) objective → ratio > 1.
        assert_eq!(ratio(Scenario::Mis, 10.0, 12.0), 1.2);
        assert_eq!(ratio(Scenario::MaxCut, 0.0, 0.0), 1.0);
        assert!(ratio(Scenario::MaxCut, 0.0, 3.0).is_infinite());
    }

    #[test]
    fn evaluate_mvc_scores_against_exact() {
        let mut rng = Pcg32::seeded(11);
        let instances = vec![
            Instance { name: "er0".into(), graph: generators::erdos_renyi(40, 0.15, &mut rng) },
            Instance { name: "ba0".into(), graph: generators::barabasi_albert(40, 3, &mut rng) },
        ];
        let cfg = EvalCfg::new(Scenario::Mvc);
        let report = evaluate(None, None, &opts(), &cfg, &instances).unwrap();
        assert_eq!(report.instances.len(), 2);
        for inst in &report.instances {
            assert!(inst.ref_optimal, "exact should prove optimality at n=40");
            assert_eq!(inst.ref_solver, "exact");
            for s in &inst.scores {
                assert!(s.feasible, "{} infeasible on {}", s.solver, inst.name);
                assert!(s.ratio >= 1.0, "{} ratio {} < 1", s.solver, s.ratio);
            }
            // 2-approx guarantee holds against the proven optimum.
            let approx = inst.scores.iter().find(|s| s.solver == "approx2").unwrap();
            assert!(approx.ratio <= 2.0);
        }
        assert_eq!(report.infeasible_count(), 0);
        assert!(report.worst_ratio() >= 1.0);
        assert!(report.mean_ratio("greedy").unwrap() >= 1.0);
        assert!(report.mean_ratio("rl").is_none());
    }

    #[test]
    fn evaluate_mis_uses_complement_duality() {
        let mut rng = Pcg32::seeded(12);
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let cfg = EvalCfg::new(Scenario::Mis);
        let instances = vec![Instance { name: "er".into(), graph: g.clone() }];
        let report = evaluate(None, None, &opts(), &cfg, &instances).unwrap();
        let inst = &report.instances[0];
        let exact = inst.scores.iter().find(|s| s.solver == "exact").unwrap();
        assert!(exact.optimal);
        assert!(exact.feasible);
        // |MIS| + |MVC| = n.
        let mvc = solvers::exact_mvc(&g, Duration::from_secs(10));
        assert_eq!(exact.objective as usize + mvc.size, g.n);
    }

    #[test]
    fn evaluate_maxcut_reference_is_best_feasible() {
        let mut rng = Pcg32::seeded(13);
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let cfg = EvalCfg::new(Scenario::MaxCut);
        let instances = vec![Instance { name: "er".into(), graph: g }];
        let report = evaluate(None, None, &opts(), &cfg, &instances).unwrap();
        let inst = &report.instances[0];
        assert!(!inst.ref_optimal);
        // The reference solver's own ratio is exactly 1.
        let r = inst.scores.iter().find(|s| s.solver == inst.ref_solver).unwrap();
        assert_eq!(r.ratio, 1.0);
        assert!(inst.scores.iter().all(|s| s.ratio >= 1.0));
    }

    #[test]
    fn exact_cap_skips_exact_but_keeps_heuristics() {
        let mut rng = Pcg32::seeded(14);
        let g = generators::erdos_renyi(60, 0.1, &mut rng);
        let mut cfg = EvalCfg::new(Scenario::Mvc);
        cfg.exact_node_cap = 10;
        let instances = vec![Instance { name: "big".into(), graph: g }];
        let report = evaluate(None, None, &opts(), &cfg, &instances).unwrap();
        let inst = &report.instances[0];
        assert!(inst.scores.iter().all(|s| s.solver != "exact"));
        assert!(inst.scores.len() >= 2, "greedy + approx2 still scored");
        assert!(!inst.ref_optimal);
    }

    #[test]
    fn report_json_has_schema_fields() {
        let mut rng = Pcg32::seeded(15);
        let g = generators::erdos_renyi(25, 0.2, &mut rng);
        let cfg = EvalCfg::new(Scenario::Mvc);
        let instances = vec![Instance { name: "er".into(), graph: g }];
        let report = evaluate(None, None, &opts(), &cfg, &instances).unwrap();
        let rendered = report.to_json().render();
        let parsed = Json::parse(&rendered).unwrap();
        for key in ["scenario", "instances", "summary"] {
            assert!(parsed.get(key).is_some(), "missing {key}");
        }
        let summary = parsed.get("summary").unwrap();
        for key in ["instances", "worst_ratio", "infeasible", "solvers"] {
            assert!(summary.get(key).is_some(), "missing summary.{key}");
        }
    }
}
