//! Compressed-sparse-row undirected graph.

use anyhow::{bail, Result};

/// An undirected simple graph in CSR form. Each edge {u,v} appears in both
/// adjacency lists; `m` counts undirected edges once.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    /// Number of nodes.
    pub n: usize,
    /// Number of undirected edges.
    pub m: usize,
    /// CSR row offsets, length n+1.
    pub row_ptr: Vec<usize>,
    /// CSR column indices, length 2m, each row sorted ascending.
    pub col_idx: Vec<u32>,
}

impl Graph {
    /// Build from an undirected edge list; duplicates and self-loops are
    /// rejected (the paper's graphs are simple).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Result<Graph> {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            let (u, v) = (u as usize, v as usize);
            if u >= n || v >= n {
                bail!("edge ({u},{v}) out of range for n={n}");
            }
            if u == v {
                bail!("self-loop at node {u}");
            }
            adj[u].push(v as u32);
            adj[v].push(u as u32);
        }
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::with_capacity(2 * edges.len());
        row_ptr.push(0);
        for list in adj.iter_mut() {
            list.sort_unstable();
            if list.windows(2).any(|w| w[0] == w[1]) {
                bail!("duplicate edge detected");
            }
            col_idx.extend_from_slice(list);
            row_ptr.push(col_idx.len());
        }
        Ok(Graph { n, m: edges.len(), row_ptr, col_idx })
    }

    /// Empty graph on n nodes.
    pub fn empty(n: usize) -> Graph {
        Graph { n, m: 0, row_ptr: vec![0; n + 1], col_idx: Vec::new() }
    }

    /// Neighbors of `v` (sorted).
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[v]..self.row_ptr[v + 1]]
    }

    /// Degree of node v.
    pub fn degree(&self, v: usize) -> usize {
        self.row_ptr[v + 1] - self.row_ptr[v]
    }

    /// Whether the undirected edge {u, v} exists.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// Undirected edge list (u < v).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.m);
        for u in 0..self.n {
            for &v in self.neighbors(u) {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// Edge probability rho = m / C(n,2) (Table 1's last column).
    pub fn edge_probability(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        self.m as f64 / (self.n as f64 * (self.n as f64 - 1.0) / 2.0)
    }

    /// Densify rows [row0, row0+rows) into `out` (rows x width, row-major
    /// f32; `width >= n` allows bucket padding), skipping nodes marked
    /// removed. This materializes one shard's sub-adjacency-matrix A^i
    /// (Fig. 2) for the XLA compute path.
    pub fn densify_rows(
        &self,
        row0: usize,
        rows: usize,
        width: usize,
        removed: &[bool],
        out: &mut [f32],
    ) {
        assert!(width >= self.n, "width {width} < graph n {}", self.n);
        assert_eq!(out.len(), rows * width);
        assert!(removed.len() >= self.n);
        out.fill(0.0);
        for r in 0..rows {
            let v = row0 + r;
            if v >= self.n || removed[v] {
                continue;
            }
            let base = r * width;
            for &u in self.neighbors(v) {
                if !removed[u as usize] {
                    out[base + u as usize] = 1.0;
                }
            }
        }
    }

    /// Directed shard edges for the sparse compute path (DESIGN.md §7):
    /// every (local source row, global destination column) pair whose
    /// source lies in rows [row0, row0+rows) of this graph and whose
    /// endpoints are both alive under `removed`. Enumerated row-major with
    /// ascending destinations — the canonical order `SparseShard` tiles
    /// (and python/tests/dist_sim.py `build_tiles` mirrors). Each
    /// undirected edge {u,v} yields up to two entries across the shard set:
    /// u→v on u's owner and v→u on v's owner, exactly the two dense
    /// sub-adjacency cells it occupies.
    pub fn shard_edges(&self, row0: usize, rows: usize, removed: &[bool]) -> Vec<(u32, u32)> {
        assert!(removed.len() >= self.n);
        let mut out = Vec::new();
        for r in 0..rows {
            let v = row0 + r;
            if v >= self.n || removed[v] {
                continue;
            }
            for &u in self.neighbors(v) {
                if !removed[u as usize] {
                    out.push((r as u32, u));
                }
            }
        }
        out
    }

    /// Live out-degree of each row in [row0, row0+rows) under `removed`
    /// (rows past n or removed count 0) — the degree vector the sparse
    /// `embed_pre_sp` stage consumes instead of row-summing a dense A.
    pub fn live_degrees(&self, row0: usize, rows: usize, removed: &[bool]) -> Vec<u32> {
        let mut deg = vec![0u32; rows];
        for r in 0..rows {
            let v = row0 + r;
            if v >= self.n || removed[v] {
                continue;
            }
            deg[r] =
                self.neighbors(v).iter().filter(|&&u| !removed[u as usize]).count() as u32;
        }
        deg
    }

    /// Total remaining (uncovered) edges given removed-node marks.
    pub fn remaining_edges(&self, removed: &[bool]) -> usize {
        let mut cnt = 0;
        for u in 0..self.n {
            if removed[u] {
                continue;
            }
            for &v in self.neighbors(u) {
                if (u as u32) < v && !removed[v as usize] {
                    cnt += 1;
                }
            }
        }
        cnt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap()
    }

    #[test]
    fn csr_structure() {
        let g = triangle();
        assert_eq!(g.n, 3);
        assert_eq!(g.m, 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.degree(1), 2);
        assert!(g.has_edge(2, 0));
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn rejects_bad_edges() {
        assert!(Graph::from_edges(2, &[(0, 0)]).is_err());
        assert!(Graph::from_edges(2, &[(0, 3)]).is_err());
        assert!(Graph::from_edges(3, &[(0, 1), (1, 0)]).is_err());
    }

    #[test]
    fn edges_roundtrip() {
        let g = triangle();
        let e = g.edges();
        let g2 = Graph::from_edges(3, &e).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn densify_respects_removed() {
        let g = triangle();
        let mut out = vec![0.0; 2 * 3];
        g.densify_rows(0, 2, 3, &[false, false, true], &mut out);
        // row 0 (node 0): neighbor 1 only (2 removed); row 1 (node 1): neighbor 0.
        assert_eq!(out, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
        g.densify_rows(1, 2, 3, &[false, false, false], &mut out);
        // rows for nodes 1 and 2
        assert_eq!(out, vec![1.0, 0.0, 1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn densify_pads_past_n() {
        let g = triangle();
        let mut out = vec![7.0; 2 * 3];
        g.densify_rows(2, 2, 3, &[false; 3], &mut out); // row 3 is padding
        assert_eq!(&out[3..], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn shard_edges_match_densified_rows() {
        // The sparse edge list must cover exactly the nonzero cells of the
        // dense sub-adjacency, in row-major order.
        let g = triangle();
        let removed = [false, true, false];
        let edges = g.shard_edges(0, 2, &removed);
        assert_eq!(edges, vec![(0, 2)]); // node 0 -> 2 (1 removed); row 1 = removed node
        let mut dense = vec![0.0; 2 * 3];
        g.densify_rows(0, 2, 3, &removed, &mut dense);
        let mut nonzero: Vec<(u32, u32)> = Vec::new();
        for r in 0..2usize {
            for u in 0..3usize {
                if dense[r * 3 + u] != 0.0 {
                    nonzero.push((r as u32, u as u32));
                }
            }
        }
        assert_eq!(edges, nonzero);
        // Padding rows past n contribute nothing.
        assert!(g.shard_edges(2, 4, &[false; 3]).iter().all(|&(r, _)| r == 0));
    }

    #[test]
    fn live_degrees_track_removals() {
        let g = triangle();
        assert_eq!(g.live_degrees(0, 3, &[false; 3]), vec![2, 2, 2]);
        assert_eq!(g.live_degrees(0, 3, &[false, true, false]), vec![1, 0, 1]);
        assert_eq!(g.live_degrees(1, 4, &[false; 3]), vec![2, 2, 0, 0]); // padded
    }

    #[test]
    fn remaining_edges_counts() {
        let g = triangle();
        assert_eq!(g.remaining_edges(&[false; 3]), 3);
        assert_eq!(g.remaining_edges(&[true, false, false]), 1);
        assert_eq!(g.remaining_edges(&[true, true, false]), 0);
    }

    #[test]
    fn edge_probability_triangle() {
        assert!((triangle().edge_probability() - 1.0).abs() < 1e-12);
    }
}
