//! Compressed-sparse-row undirected graph.

use anyhow::{bail, ensure, Result};

/// An undirected simple graph in CSR form. Each edge {u,v} appears in both
/// adjacency lists; `m` counts undirected edges once.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    /// Number of nodes.
    pub n: usize,
    /// Number of undirected edges.
    pub m: usize,
    /// CSR row offsets, length n+1.
    pub row_ptr: Vec<usize>,
    /// CSR column indices, length 2m, each row sorted ascending.
    pub col_idx: Vec<u32>,
}

impl Graph {
    /// Build from an undirected edge list; duplicates and self-loops are
    /// rejected (the paper's graphs are simple).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Result<Graph> {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in edges {
            let (u, v) = (u as usize, v as usize);
            if u >= n || v >= n {
                bail!("edge ({u},{v}) out of range for n={n}");
            }
            if u == v {
                bail!("self-loop at node {u}");
            }
            adj[u].push(v as u32);
            adj[v].push(u as u32);
        }
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::with_capacity(2 * edges.len());
        row_ptr.push(0);
        for list in adj.iter_mut() {
            list.sort_unstable();
            if list.windows(2).any(|w| w[0] == w[1]) {
                bail!("duplicate edge detected");
            }
            col_idx.extend_from_slice(list);
            row_ptr.push(col_idx.len());
        }
        Ok(Graph { n, m: edges.len(), row_ptr, col_idx })
    }

    /// Empty graph on n nodes.
    pub fn empty(n: usize) -> Graph {
        Graph { n, m: 0, row_ptr: vec![0; n + 1], col_idx: Vec::new() }
    }

    /// Neighbors of `v` (sorted).
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[v]..self.row_ptr[v + 1]]
    }

    /// Degree of node v.
    pub fn degree(&self, v: usize) -> usize {
        self.row_ptr[v + 1] - self.row_ptr[v]
    }

    /// Whether the undirected edge {u, v} exists.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// Undirected edge list (u < v).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.m);
        for u in 0..self.n {
            for &v in self.neighbors(u) {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// Edge probability rho = m / C(n,2) (Table 1's last column).
    pub fn edge_probability(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        self.m as f64 / (self.n as f64 * (self.n as f64 - 1.0) / 2.0)
    }

    /// Densify rows [row0, row0+rows) into `out` (rows x width, row-major
    /// f32; `width >= n` allows bucket padding), skipping nodes marked
    /// removed. This materializes one shard's sub-adjacency-matrix A^i
    /// (Fig. 2) for the XLA compute path.
    pub fn densify_rows(
        &self,
        row0: usize,
        rows: usize,
        width: usize,
        removed: &[bool],
        out: &mut [f32],
    ) {
        assert!(width >= self.n, "width {width} < graph n {}", self.n);
        assert_eq!(out.len(), rows * width);
        assert!(removed.len() >= self.n);
        out.fill(0.0);
        for r in 0..rows {
            let v = row0 + r;
            if v >= self.n || removed[v] {
                continue;
            }
            let base = r * width;
            for &u in self.neighbors(v) {
                if !removed[u as usize] {
                    out[base + u as usize] = 1.0;
                }
            }
        }
    }

    /// Directed shard edges for the sparse compute path (DESIGN.md §7):
    /// every (local source row, global destination column) pair whose
    /// source lies in rows [row0, row0+rows) of this graph and whose
    /// endpoints are both alive under `removed`. Enumerated row-major with
    /// ascending destinations — the canonical order `SparseShard` tiles
    /// (and python/tests/dist_sim.py `build_tiles` mirrors). Each
    /// undirected edge {u,v} yields up to two entries across the shard set:
    /// u→v on u's owner and v→u on v's owner, exactly the two dense
    /// sub-adjacency cells it occupies.
    pub fn shard_edges(&self, row0: usize, rows: usize, removed: &[bool]) -> Vec<(u32, u32)> {
        assert!(removed.len() >= self.n);
        let mut out = Vec::new();
        for r in 0..rows {
            let v = row0 + r;
            if v >= self.n || removed[v] {
                continue;
            }
            for &u in self.neighbors(v) {
                if !removed[u as usize] {
                    out.push((r as u32, u));
                }
            }
        }
        out
    }

    /// Live out-degree of each row in [row0, row0+rows) under `removed`
    /// (rows past n or removed count 0) — the degree vector the sparse
    /// `embed_pre_sp` stage consumes instead of row-summing a dense A.
    pub fn live_degrees(&self, row0: usize, rows: usize, removed: &[bool]) -> Vec<u32> {
        let mut deg = vec![0u32; rows];
        for r in 0..rows {
            let v = row0 + r;
            if v >= self.n || removed[v] {
                continue;
            }
            deg[r] =
                self.neighbors(v).iter().filter(|&&u| !removed[u as usize]).count() as u32;
        }
        deg
    }

    /// Total remaining (uncovered) edges given removed-node marks.
    pub fn remaining_edges(&self, removed: &[bool]) -> usize {
        let mut cnt = 0;
        for u in 0..self.n {
            if removed[u] {
                continue;
            }
            for &v in self.neighbors(u) {
                if (u as u32) < v && !removed[v as usize] {
                    cnt += 1;
                }
            }
        }
        cnt
    }
}

/// Streaming two-pass CSR builder for paper-scale inputs (DESIGN.md §7).
///
/// `Graph::from_edges` materializes a `Vec<Vec<u32>>` adjacency — fine for
/// bench-sized graphs, ruinous at 30M edges. The builder instead takes two
/// identical passes of undirected-edge callbacks: `count` tallies endpoint
/// degrees, `begin_fill` turns the tallies into row offsets, `fill` places
/// the two directed entries of each edge at its endpoints' cursors, and
/// `finish` sorts each row in place, drops duplicate edges, and produces
/// the `Graph`. Peak memory is O(N + E) with no global edge sort and no
/// per-node `Vec` — file loaders re-read the input for the second pass, so
/// the edges themselves are never held in memory at once.
#[derive(Debug)]
pub struct CsrBuilder {
    n: usize,
    /// Count pass: per-node degree tally; fill pass: per-node write cursor.
    cursor: Vec<usize>,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    filling: bool,
}

impl CsrBuilder {
    /// Start a builder for `n` nodes in the count phase.
    pub fn new(n: usize) -> CsrBuilder {
        CsrBuilder {
            n,
            cursor: vec![0; n],
            row_ptr: Vec::new(),
            col_idx: Vec::new(),
            filling: false,
        }
    }

    /// Skip the count phase: adopt a precomputed per-node degree tally
    /// (each undirected edge counted once at both endpoints) and go
    /// straight to the fill phase. Used by loaders that tally degrees
    /// while interning node ids on their first file pass.
    pub fn from_degrees(degrees: Vec<usize>) -> CsrBuilder {
        let mut b = CsrBuilder {
            n: degrees.len(),
            cursor: degrees,
            row_ptr: Vec::new(),
            col_idx: Vec::new(),
            filling: false,
        };
        b.begin_fill();
        b
    }

    /// Count-phase callback: tally the undirected edge {u, v} at both
    /// endpoints. Self-loops and out-of-range endpoints are errors;
    /// duplicate edges are accepted here and dropped in `finish`.
    pub fn count(&mut self, u: u32, v: u32) -> Result<()> {
        ensure!(!self.filling, "count() called after begin_fill()");
        let (u, v) = (u as usize, v as usize);
        if u >= self.n || v >= self.n {
            bail!("edge ({u},{v}) out of range for n={}", self.n);
        }
        if u == v {
            bail!("self-loop at node {u}");
        }
        self.cursor[u] += 1;
        self.cursor[v] += 1;
        Ok(())
    }

    /// End the count phase: prefix-sum the tallies into row offsets and
    /// allocate the column array (the single O(E) allocation).
    pub fn begin_fill(&mut self) {
        assert!(!self.filling, "begin_fill() called twice");
        let mut row_ptr = Vec::with_capacity(self.n + 1);
        row_ptr.push(0usize);
        let mut total = 0usize;
        for d in self.cursor.iter_mut() {
            let start = total;
            total += *d;
            *d = start; // cursor becomes the row's next write offset
            row_ptr.push(total);
        }
        self.row_ptr = row_ptr;
        self.col_idx = vec![0u32; total];
        self.filling = true;
    }

    /// Fill-phase callback: place both directed entries of {u, v}. The
    /// fill pass must replay exactly the edges given to the count pass
    /// (any order); a divergent replay is detected and reported.
    pub fn fill(&mut self, u: u32, v: u32) -> Result<()> {
        ensure!(self.filling, "fill() called before begin_fill()");
        let (ui, vi) = (u as usize, v as usize);
        if ui >= self.n || vi >= self.n {
            bail!("edge ({ui},{vi}) out of range for n={}", self.n);
        }
        if ui == vi {
            bail!("self-loop at node {ui}");
        }
        if self.cursor[ui] == self.row_ptr[ui + 1] || self.cursor[vi] == self.row_ptr[vi + 1] {
            bail!("fill pass diverged from count pass at edge ({ui},{vi})");
        }
        self.col_idx[self.cursor[ui]] = v;
        self.cursor[ui] += 1;
        self.col_idx[self.cursor[vi]] = u;
        self.cursor[vi] += 1;
        Ok(())
    }

    /// Finish: sort each row, drop duplicate edges (compacting in place),
    /// and return the graph. Errors if the fill pass placed fewer edges
    /// than the count pass promised.
    pub fn finish(mut self) -> Result<Graph> {
        ensure!(self.filling, "finish() called before begin_fill()");
        for v in 0..self.n {
            if self.cursor[v] != self.row_ptr[v + 1] {
                bail!(
                    "fill pass placed {} of {} counted entries at node {v}",
                    self.cursor[v] - self.row_ptr[v],
                    self.row_ptr[v + 1] - self.row_ptr[v]
                );
            }
        }
        // In-place per-row sort + dedup: the write head never passes the
        // read head, so compaction reuses the column array.
        let mut write = 0usize;
        let mut new_ptr = vec![0usize; self.n + 1];
        for v in 0..self.n {
            let (s, e) = (self.row_ptr[v], self.row_ptr[v + 1]);
            self.col_idx[s..e].sort_unstable();
            let row_start = write;
            for i in s..e {
                let x = self.col_idx[i];
                if write == row_start || self.col_idx[write - 1] != x {
                    self.col_idx[write] = x;
                    write += 1;
                }
            }
            new_ptr[v + 1] = write;
        }
        self.col_idx.truncate(write);
        if write % 2 != 0 {
            bail!("asymmetric fill: odd directed-entry count {write}");
        }
        Ok(Graph { n: self.n, m: write / 2, row_ptr: new_ptr, col_idx: self.col_idx })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap()
    }

    #[test]
    fn csr_structure() {
        let g = triangle();
        assert_eq!(g.n, 3);
        assert_eq!(g.m, 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.degree(1), 2);
        assert!(g.has_edge(2, 0));
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn rejects_bad_edges() {
        assert!(Graph::from_edges(2, &[(0, 0)]).is_err());
        assert!(Graph::from_edges(2, &[(0, 3)]).is_err());
        assert!(Graph::from_edges(3, &[(0, 1), (1, 0)]).is_err());
    }

    #[test]
    fn edges_roundtrip() {
        let g = triangle();
        let e = g.edges();
        let g2 = Graph::from_edges(3, &e).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn densify_respects_removed() {
        let g = triangle();
        let mut out = vec![0.0; 2 * 3];
        g.densify_rows(0, 2, 3, &[false, false, true], &mut out);
        // row 0 (node 0): neighbor 1 only (2 removed); row 1 (node 1): neighbor 0.
        assert_eq!(out, vec![0.0, 1.0, 0.0, 1.0, 0.0, 0.0]);
        g.densify_rows(1, 2, 3, &[false, false, false], &mut out);
        // rows for nodes 1 and 2
        assert_eq!(out, vec![1.0, 0.0, 1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn densify_pads_past_n() {
        let g = triangle();
        let mut out = vec![7.0; 2 * 3];
        g.densify_rows(2, 2, 3, &[false; 3], &mut out); // row 3 is padding
        assert_eq!(&out[3..], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn shard_edges_match_densified_rows() {
        // The sparse edge list must cover exactly the nonzero cells of the
        // dense sub-adjacency, in row-major order.
        let g = triangle();
        let removed = [false, true, false];
        let edges = g.shard_edges(0, 2, &removed);
        assert_eq!(edges, vec![(0, 2)]); // node 0 -> 2 (1 removed); row 1 = removed node
        let mut dense = vec![0.0; 2 * 3];
        g.densify_rows(0, 2, 3, &removed, &mut dense);
        let mut nonzero: Vec<(u32, u32)> = Vec::new();
        for r in 0..2usize {
            for u in 0..3usize {
                if dense[r * 3 + u] != 0.0 {
                    nonzero.push((r as u32, u as u32));
                }
            }
        }
        assert_eq!(edges, nonzero);
        // Padding rows past n contribute nothing.
        assert!(g.shard_edges(2, 4, &[false; 3]).iter().all(|&(r, _)| r == 0));
    }

    #[test]
    fn live_degrees_track_removals() {
        let g = triangle();
        assert_eq!(g.live_degrees(0, 3, &[false; 3]), vec![2, 2, 2]);
        assert_eq!(g.live_degrees(0, 3, &[false, true, false]), vec![1, 0, 1]);
        assert_eq!(g.live_degrees(1, 4, &[false; 3]), vec![2, 2, 0, 0]); // padded
    }

    #[test]
    fn remaining_edges_counts() {
        let g = triangle();
        assert_eq!(g.remaining_edges(&[false; 3]), 3);
        assert_eq!(g.remaining_edges(&[true, false, false]), 1);
        assert_eq!(g.remaining_edges(&[true, true, false]), 0);
    }

    #[test]
    fn edge_probability_triangle() {
        assert!((triangle().edge_probability() - 1.0).abs() < 1e-12);
    }

    fn build_streamed(n: usize, edges: &[(u32, u32)]) -> Result<Graph> {
        let mut b = CsrBuilder::new(n);
        for &(u, v) in edges {
            b.count(u, v)?;
        }
        b.begin_fill();
        for &(u, v) in edges {
            b.fill(u, v)?;
        }
        b.finish()
    }

    #[test]
    fn builder_matches_from_edges() {
        let edges = [(0, 1), (1, 2), (0, 2), (3, 1)];
        let g = build_streamed(4, &edges).unwrap();
        assert_eq!(g, Graph::from_edges(4, &edges).unwrap());
    }

    #[test]
    fn builder_drops_duplicate_edges() {
        // Duplicates in either orientation collapse to one edge.
        let g = build_streamed(3, &[(0, 1), (1, 0), (0, 1), (1, 2)]).unwrap();
        assert_eq!(g, Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap());
    }

    #[test]
    fn builder_rejects_bad_input() {
        assert!(CsrBuilder::new(2).count(0, 0).is_err());
        assert!(CsrBuilder::new(2).count(0, 3).is_err());
        let mut b = CsrBuilder::new(2);
        b.begin_fill();
        assert!(b.count(0, 1).is_err()); // count after begin_fill
        assert!(b.fill(0, 1).is_err()); // fill of an uncounted edge
    }

    #[test]
    fn builder_detects_divergent_fill_pass() {
        let mut b = CsrBuilder::new(4);
        b.count(0, 1).unwrap();
        b.count(2, 3).unwrap();
        b.begin_fill();
        b.fill(0, 1).unwrap();
        // Fill pass stops early: finish must notice nodes 2 and 3.
        assert!(b.finish().is_err());
    }

    #[test]
    fn builder_from_degrees_matches_count_phase() {
        let edges = [(0u32, 1u32), (1, 2), (0, 2)];
        let mut deg = vec![0usize; 3];
        for &(u, v) in &edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut b = CsrBuilder::from_degrees(deg);
        for &(u, v) in &edges {
            b.fill(u, v).unwrap();
        }
        assert_eq!(b.finish().unwrap(), triangle());
    }

    #[test]
    fn prop_builder_equals_from_edges() {
        use crate::util::prop;
        use crate::util::rng::Pcg32;
        prop::check(
            "csr-builder-equiv",
            30,
            |r| {
                let n = 2 + r.gen_range(40);
                let mut edges = Vec::new();
                for u in 0..n as u32 {
                    for v in (u + 1)..n as u32 {
                        if r.next_f64() < 0.2 {
                            edges.push((u, v));
                        }
                    }
                }
                (n, edges)
            },
            |(n, edges)| {
                build_streamed(*n, edges).unwrap() == Graph::from_edges(*n, edges).unwrap()
            },
        );
    }
}
