//! Dataset statistics (Table 1 rows) and structural measures.

use super::csr::Graph;
use crate::util::rng::Pcg32;

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct DatasetStats {
    /// Dataset label.
    pub name: String,
    /// Node count |V|.
    pub nodes: usize,
    /// Undirected edge count |E|.
    pub edges: usize,
    /// Edge probability m / C(n,2).
    pub rho: f64,
    /// Maximum node degree.
    pub max_degree: usize,
    /// Mean node degree 2m/n.
    pub mean_degree: f64,
}

/// Compute one Table-1 row for a graph.
pub fn dataset_stats(name: &str, g: &Graph) -> DatasetStats {
    DatasetStats {
        name: name.to_string(),
        nodes: g.n,
        edges: g.m,
        rho: g.edge_probability(),
        max_degree: (0..g.n).map(|v| g.degree(v)).max().unwrap_or(0),
        mean_degree: if g.n == 0 { 0.0 } else { 2.0 * g.m as f64 / g.n as f64 },
    }
}

/// Sampled average local clustering coefficient (exact when samples >= n).
pub fn clustering_coefficient(g: &Graph, samples: usize, rng: &mut Pcg32) -> f64 {
    if g.n == 0 {
        return 0.0;
    }
    let nodes: Vec<usize> = if samples >= g.n {
        (0..g.n).collect()
    } else {
        (0..samples).map(|_| rng.gen_range(g.n)).collect()
    };
    let mut total = 0.0;
    let mut counted = 0usize;
    for &v in &nodes {
        let nbrs = g.neighbors(v);
        let d = nbrs.len();
        if d < 2 {
            continue;
        }
        let mut tri = 0usize;
        for i in 0..d {
            for j in (i + 1)..d {
                if g.has_edge(nbrs[i] as usize, nbrs[j] as usize) {
                    tri += 1;
                }
            }
        }
        total += 2.0 * tri as f64 / (d * (d - 1)) as f64;
        counted += 1;
    }
    if counted == 0 { 0.0 } else { total / counted as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_triangle() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap();
        let s = dataset_stats("tri", &g);
        assert_eq!(s.nodes, 3);
        assert_eq!(s.edges, 3);
        assert_eq!(s.max_degree, 2);
        assert!((s.rho - 1.0).abs() < 1e-12);
        let mut rng = Pcg32::seeded(0);
        assert!((clustering_coefficient(&g, 100, &mut rng) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn clustering_of_star_is_zero() {
        let g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let mut rng = Pcg32::seeded(0);
        assert_eq!(clustering_coefficient(&g, 100, &mut rng), 0.0);
    }
}
