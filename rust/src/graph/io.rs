//! Graph file I/O: SNAP-style edge lists (whitespace-separated `u v`
//! pairs, `#`/`%` comments) and MatrixMarket coordinate files (`.mtx`),
//! the two formats real datasets ship in (SNAP, NetworkRepository,
//! SuiteSparse). Loaders stream the file in two passes through
//! [`CsrBuilder`](super::csr::CsrBuilder) — degrees are tallied on the
//! first pass and entries placed on the second — so CSR is built directly
//! with no dense adjacency, no per-node `Vec`, and no intermediate edge
//! `Vec` sort. Peak memory is O(N + E), which keeps paper-scale (30M+
//! edge) graphs inside the DESIGN.md §7 memory model.

use super::csr::{CsrBuilder, Graph};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;

/// Walk a file line by line through a reused buffer (no per-line String
/// allocation), handing each line and its 1-based number to `f`.
fn for_each_line(path: &Path, mut f: impl FnMut(usize, &str) -> Result<()>) -> Result<()> {
    let file =
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = std::io::BufReader::new(file);
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        if r.read_line(&mut line).context("read error")? == 0 {
            return Ok(());
        }
        lineno += 1;
        f(lineno, line.trim())?;
    }
}

fn parse_id(tok: &str, lineno: usize, what: &str) -> Result<u64> {
    tok.parse::<u64>()
        .map_err(|_| anyhow!("line {lineno}: bad {what} '{tok}' (unsigned integer expected)"))
}

/// Parse a `u v` data line (extra trailing tokens — weights, timestamps —
/// are ignored, as real SNAP dumps carry them).
fn parse_pair(t: &str, lineno: usize) -> Result<(u64, u64)> {
    let mut it = t.split_whitespace();
    let u = it.next().ok_or_else(|| anyhow!("line {lineno}: missing u"))?;
    let v = it
        .next()
        .ok_or_else(|| anyhow!("line {lineno}: missing v (expected 'u v' pair)"))?;
    Ok((parse_id(u, lineno, "node id")?, parse_id(v, lineno, "node id")?))
}

fn edge_list_skip(t: &str) -> bool {
    t.is_empty() || t.starts_with('#') || t.starts_with('%')
}

/// Read a SNAP-style edge-list file, streaming. Node ids may be arbitrary
/// (non-contiguous); they are compacted to 0..n preserving first-appearance
/// order. Self-loops are dropped quietly and duplicate edges deduplicated
/// (both are common in real dumps); malformed lines error with their line
/// number. Isolated nodes cannot be represented in this format.
pub fn read_edge_list(path: impl AsRef<Path>) -> Result<Graph> {
    let path = path.as_ref();
    read_edge_list_inner(path).with_context(|| format!("reading {}", path.display()))
}

fn read_edge_list_inner(path: &Path) -> Result<Graph> {
    // Pass 1: intern ids in first-appearance order and tally degrees.
    let mut ids: HashMap<u64, u32> = HashMap::new();
    let mut deg: Vec<usize> = Vec::new();
    for_each_line(path, |lineno, t| {
        if edge_list_skip(t) {
            return Ok(());
        }
        let (u, v) = parse_pair(t, lineno)?;
        if u == v {
            return Ok(()); // self-loop
        }
        let mut intern = |raw: u64| -> Result<usize> {
            let next = ids.len();
            let slot = *ids.entry(raw).or_insert(next as u32) as usize;
            if slot == next {
                if next >= u32::MAX as usize {
                    bail!("line {lineno}: more than {} distinct node ids", u32::MAX);
                }
                deg.push(0);
            }
            Ok(slot)
        };
        let a = intern(u)?;
        let b = intern(v)?;
        deg[a] += 1;
        deg[b] += 1;
        Ok(())
    })?;
    // Pass 2: re-read the file and place entries straight into CSR.
    let mut bld = CsrBuilder::from_degrees(deg);
    for_each_line(path, |lineno, t| {
        if edge_list_skip(t) {
            return Ok(());
        }
        let (u, v) = parse_pair(t, lineno)?;
        if u == v {
            return Ok(());
        }
        let a = *ids.get(&u).ok_or_else(|| anyhow!("file changed between passes"))?;
        let b = *ids.get(&v).ok_or_else(|| anyhow!("file changed between passes"))?;
        bld.fill(a, b).with_context(|| format!("line {lineno}"))
    })?;
    bld.finish()
}

/// Scan a MatrixMarket coordinate file: validate the banner, the size
/// line, and every entry (1-based indices inside the declared square
/// dimension, entry count matching the declared nnz), calling `on_edge`
/// with each off-diagonal entry as 0-based endpoints. Diagonal entries
/// (self-loops) are dropped quietly. Returns the declared node count.
fn scan_mtx(path: &Path, mut on_edge: impl FnMut(usize, u32, u32) -> Result<()>) -> Result<usize> {
    let mut banner = false;
    let mut dims: Option<(usize, usize)> = None;
    let mut entries = 0usize;
    for_each_line(path, |lineno, t| {
        if !banner {
            let lower = t.to_ascii_lowercase();
            let mut it = lower.split_whitespace();
            if it.next() != Some("%%matrixmarket") {
                bail!("line {lineno}: missing %%MatrixMarket banner (not a .mtx file?)");
            }
            let object = it.next().unwrap_or("");
            let format = it.next().unwrap_or("");
            let field = it.next().unwrap_or("");
            let symmetry = it.next().unwrap_or("");
            if object != "matrix" || format != "coordinate" {
                bail!("line {lineno}: unsupported MatrixMarket type '{object} {format}' \
                       (only 'matrix coordinate' is supported)");
            }
            if !matches!(field, "pattern" | "real" | "integer" | "double") {
                bail!("line {lineno}: unsupported MatrixMarket field '{field}'");
            }
            if !matches!(symmetry, "general" | "symmetric") {
                bail!("line {lineno}: unsupported MatrixMarket symmetry '{symmetry}'");
            }
            banner = true;
            return Ok(());
        }
        if t.is_empty() || t.starts_with('%') {
            return Ok(());
        }
        if dims.is_none() {
            let mut it = t.split_whitespace();
            let mut next = |what: &str| -> Result<usize> {
                let tok = it
                    .next()
                    .ok_or_else(|| anyhow!("line {lineno}: size line missing {what}"))?;
                Ok(parse_id(tok, lineno, what)? as usize)
            };
            let (rows, cols, nnz) = (next("rows")?, next("cols")?, next("nnz")?);
            if rows != cols {
                bail!("line {lineno}: non-square {rows}x{cols} matrix is not an undirected graph");
            }
            if rows > u32::MAX as usize {
                bail!("line {lineno}: {rows} rows exceed the u32 node-id space");
            }
            dims = Some((rows, nnz));
            return Ok(());
        }
        let (n, nnz) = dims.unwrap();
        entries += 1;
        if entries > nnz {
            bail!("line {lineno}: more than the declared {nnz} entries");
        }
        let (i, j) = parse_pair(t, lineno)?;
        if i < 1 || j < 1 || i as usize > n || j as usize > n {
            bail!("line {lineno}: entry ({i},{j}) outside the declared {n}x{n} matrix");
        }
        if i == j {
            return Ok(()); // diagonal entry (self-loop)
        }
        on_edge(lineno, (i - 1) as u32, (j - 1) as u32)
    })?;
    if !banner {
        bail!("empty file: missing %%MatrixMarket banner");
    }
    let (n, nnz) = dims.ok_or_else(|| anyhow!("missing MatrixMarket size line"))?;
    if entries != nnz {
        bail!("declared {nnz} entries but found {entries}");
    }
    Ok(n)
}

/// Read a MatrixMarket coordinate file as an undirected graph, streaming.
/// `pattern`/`real`/`integer` fields are accepted (values ignored), with
/// `general` or `symmetric` symmetry — either way every entry contributes
/// one undirected edge and duplicates (including a `general` file listing
/// both orientations) are deduplicated. Diagonal entries are dropped.
/// Unlike the edge-list format, the declared dimension preserves isolated
/// nodes. Malformed input errors with its line number.
pub fn read_mtx(path: impl AsRef<Path>) -> Result<Graph> {
    let path = path.as_ref();
    read_mtx_inner(path).with_context(|| format!("reading {}", path.display()))
}

fn read_mtx_inner(path: &Path) -> Result<Graph> {
    // Pass 1: tally degrees (indices are already bounds-checked by scan).
    let mut deg: Vec<usize> = Vec::new();
    let n = scan_mtx(path, |_, u, v| {
        let hi = u.max(v) as usize;
        if deg.len() <= hi {
            deg.resize(hi + 1, 0);
        }
        deg[u as usize] += 1;
        deg[v as usize] += 1;
        Ok(())
    })?;
    deg.resize(n, 0); // keep trailing isolated nodes
    // Pass 2: place entries.
    let mut bld = CsrBuilder::from_degrees(deg);
    scan_mtx(path, |lineno, u, v| {
        bld.fill(u, v).with_context(|| format!("line {lineno}"))
    })?;
    bld.finish()
}

/// Read a graph file, dispatching on the extension: `.mtx` (any case) is
/// parsed as MatrixMarket, anything else as a SNAP-style edge list.
pub fn read_graph(path: impl AsRef<Path>) -> Result<Graph> {
    let p = path.as_ref();
    match p.extension().and_then(|e| e.to_str()) {
        Some(ext) if ext.eq_ignore_ascii_case("mtx") => read_mtx(p),
        _ => read_edge_list(p),
    }
}

/// Write a graph as an edge list (one `u v` line per edge, ascending).
pub fn write_edge_list(path: impl AsRef<Path>, g: &Graph) -> Result<()> {
    let path = path.as_ref();
    let file =
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    writeln!(w, "# oggm edge list: n={} m={}", g.n, g.m)?;
    for u in 0..g.n {
        for &v in g.neighbors(u) {
            if (u as u32) < v {
                writeln!(w, "{u} {v}")?;
            }
        }
    }
    Ok(())
}

/// Write a graph as a MatrixMarket coordinate pattern file (`symmetric`
/// storage: each undirected edge once in the lower triangle, 1-based).
/// Streams the CSR directly — no edge `Vec` is materialized.
pub fn write_mtx(path: impl AsRef<Path>, g: &Graph) -> Result<()> {
    let path = path.as_ref();
    let file =
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    writeln!(w, "%%MatrixMarket matrix coordinate pattern symmetric")?;
    writeln!(w, "% oggm graph: n={} m={}", g.n, g.m)?;
    writeln!(w, "{} {} {}", g.n, g.n, g.m)?;
    for u in 0..g.n {
        for &v in g.neighbors(u) {
            if (v as usize) < u {
                writeln!(w, "{} {}", u + 1, v + 1)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    struct TempDir(std::path::PathBuf);

    impl TempDir {
        fn new(tag: &str) -> TempDir {
            let dir =
                std::env::temp_dir().join(format!("oggm_io_{tag}_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            TempDir(dir)
        }

        fn file(&self, name: &str, content: &str) -> std::path::PathBuf {
            let p = self.0.join(name);
            std::fs::write(&p, content).unwrap();
            p
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            std::fs::remove_dir_all(&self.0).ok();
        }
    }

    #[test]
    fn roundtrip() {
        let dir = TempDir::new("rt");
        let p = dir.0.join("g.txt");
        let g = generators::erdos_renyi(60, 0.2, &mut Pcg32::seeded(1));
        write_edge_list(&p, &g).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g.m, g2.m);
        assert_eq!(g.n, g2.n);
    }

    #[test]
    fn handles_comments_dups_and_loops() {
        let dir = TempDir::new("cdl");
        let p = dir.file("g.txt", "# c\n% mm-style comment\n10 20\n20 10\n5 5\n10 30\n");
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.n, 3);
        assert_eq!(g.m, 2);
    }

    /// First-appearance compaction applied to a written edge list: the
    /// expected result of reading the file back.
    fn compacted(g: &Graph) -> Graph {
        let mut ids: HashMap<u32, u32> = HashMap::new();
        let mut edges = Vec::new();
        for (u, v) in g.edges() {
            let next = ids.len() as u32;
            let a = *ids.entry(u).or_insert(next);
            let next = ids.len() as u32;
            let b = *ids.entry(v).or_insert(next);
            edges.push((a.min(b), a.max(b)));
        }
        Graph::from_edges(ids.len(), &edges).unwrap()
    }

    #[test]
    fn prop_edge_list_roundtrip_up_to_compaction() {
        let dir = TempDir::new("prop_el");
        let p = dir.0.join("g.txt");
        prop::check(
            "edge-list-roundtrip",
            20,
            |r| {
                let n = 5 + r.gen_range(60);
                let rho = 0.05 + r.next_f64() * 0.3;
                generators::erdos_renyi(n, rho, r)
            },
            |g| {
                // Isolated ER nodes cannot survive the edge-list format;
                // `compacted` models exactly what a re-read must produce.
                write_edge_list(&p, g).unwrap();
                read_edge_list(&p).unwrap() == compacted(g)
            },
        );
    }

    #[test]
    fn prop_mtx_roundtrip_exact() {
        let dir = TempDir::new("prop_mtx");
        let p = dir.0.join("g.mtx");
        prop::check(
            "mtx-roundtrip",
            20,
            |r| {
                let n = 5 + r.gen_range(60);
                let rho = 0.05 + r.next_f64() * 0.3;
                generators::erdos_renyi(n, rho, r)
            },
            |g| {
                // .mtx declares n, so isolated nodes survive: exact identity.
                write_mtx(&p, g).unwrap();
                read_mtx(&p).unwrap() == *g
            },
        );
    }

    #[test]
    fn read_graph_dispatches_on_extension() {
        let dir = TempDir::new("dispatch");
        let g = generators::erdos_renyi(20, 0.3, &mut Pcg32::seeded(9));
        let mtx = dir.0.join("g.MTX");
        let txt = dir.0.join("g.txt");
        write_mtx(&mtx, &g).unwrap();
        write_edge_list(&txt, &g).unwrap();
        assert_eq!(read_graph(&mtx).unwrap(), g);
        assert_eq!(read_graph(&txt).unwrap().m, g.m);
    }

    fn err_of(res: Result<Graph>) -> String {
        format!("{:#}", res.expect_err("expected a parse error"))
    }

    #[test]
    fn edge_list_errors_carry_line_numbers() {
        let dir = TempDir::new("errs");
        // Line 3 has a lone token.
        let e = err_of(read_edge_list(dir.file("a.txt", "# c\n1 2\n7\n")));
        assert!(e.contains("line 3") && e.contains("missing v"), "{e}");
        // Line 2 has a non-numeric id.
        let e = err_of(read_edge_list(dir.file("b.txt", "1 2\nx 3\n")));
        assert!(e.contains("line 2") && e.contains("bad node id"), "{e}");
        // Line 4 overflows u64.
        let e = err_of(read_edge_list(dir.file(
            "c.txt",
            "1 2\n2 3\n\n99999999999999999999999999 4\n",
        )));
        assert!(e.contains("line 4"), "{e}");
        // Errors name the file.
        assert!(e.contains("c.txt"), "{e}");
    }

    #[test]
    fn mtx_errors_carry_line_numbers() {
        let dir = TempDir::new("mtx_errs");
        let banner = "%%MatrixMarket matrix coordinate pattern symmetric\n";
        // Not a MatrixMarket file at all.
        let e = err_of(read_mtx(dir.file("a.mtx", "1 2\n")));
        assert!(e.contains("line 1") && e.contains("banner"), "{e}");
        // Unsupported symmetry.
        let e = err_of(read_mtx(dir.file(
            "b.mtx",
            "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1\n",
        )));
        assert!(e.contains("line 1") && e.contains("symmetry"), "{e}");
        // Entry out of the declared range, on line 4 (after a comment).
        let e = err_of(read_mtx(dir.file(
            "c.mtx",
            &format!("{banner}% sizes\n3 3 2\n4 1\n2 1\n"),
        )));
        assert!(e.contains("line 4") && e.contains("outside"), "{e}");
        // Non-square.
        let e = err_of(read_mtx(dir.file("d.mtx", &format!("{banner}3 4 1\n1 2\n"))));
        assert!(e.contains("line 2") && e.contains("non-square"), "{e}");
        // Fewer entries than declared.
        let e = err_of(read_mtx(dir.file("e.mtx", &format!("{banner}3 3 5\n1 2\n"))));
        assert!(e.contains("declared 5 entries but found 1"), "{e}");
    }

    #[test]
    fn mtx_accepts_general_with_both_orientations_and_values() {
        let dir = TempDir::new("mtx_gen");
        let p = dir.file(
            "g.mtx",
            "%%MatrixMarket matrix coordinate real general\n\
             3 3 5\n1 2 0.5\n2 1 0.5\n2 2 1.0\n1 3 2.0\n3 1 2.0\n",
        );
        let g = read_mtx(&p).unwrap();
        assert_eq!(g.n, 3);
        assert_eq!(g.m, 2); // {1,2} and {1,3}; the diagonal entry dropped
        assert!(g.has_edge(0, 1) && g.has_edge(0, 2));
    }

    #[test]
    fn mtx_preserves_isolated_nodes() {
        let dir = TempDir::new("mtx_iso");
        let p = dir.file(
            "g.mtx",
            "%%MatrixMarket matrix coordinate pattern symmetric\n5 5 1\n2 1\n",
        );
        let g = read_mtx(&p).unwrap();
        assert_eq!(g.n, 5);
        assert_eq!(g.m, 1);
        assert_eq!(g.degree(4), 0);
    }
}
