//! Edge-list I/O (whitespace-separated `u v` pairs, `#` comments), the
//! format used by NetworkRepository/SNAP dumps, so real datasets can be
//! dropped in when available.

use super::csr::Graph;
use anyhow::{Context, Result};
use std::io::{BufRead, Write};
use std::path::Path;

/// Read an edge-list file. Node ids may be arbitrary (non-contiguous);
/// they are compacted to 0..n preserving first-appearance order.
pub fn read_edge_list(path: impl AsRef<Path>) -> Result<Graph> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let mut ids = std::collections::HashMap::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let intern = |ids: &mut std::collections::HashMap<u64, u32>, raw: u64| {
        let next = ids.len() as u32;
        *ids.entry(raw).or_insert(next)
    };
    for (lineno, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u64 = it.next().context("missing u")?.parse()
            .with_context(|| format!("line {}", lineno + 1))?;
        let v: u64 = it.next().context("missing v")?.parse()
            .with_context(|| format!("line {}", lineno + 1))?;
        if u == v {
            continue; // drop self-loops quietly (common in dumps)
        }
        let (a, b) = (intern(&mut ids, u), intern(&mut ids, v));
        let (a, b) = if a < b { (a, b) } else { (b, a) };
        edges.push((a, b));
    }
    edges.sort_unstable();
    edges.dedup();
    Graph::from_edges(ids.len(), &edges)
}

/// Write a graph as an edge list.
pub fn write_edge_list(path: impl AsRef<Path>, g: &Graph) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    writeln!(w, "# oggm edge list: n={} m={}", g.n, g.m)?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("oggm_io_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        let g = generators::erdos_renyi(60, 0.2, &mut Pcg32::seeded(1));
        write_edge_list(&p, &g).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g.m, g2.m);
        assert_eq!(g.n, g2.n);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn handles_comments_dups_and_loops() {
        let dir = std::env::temp_dir().join(format!("oggm_io2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        std::fs::write(&p, "# c\n10 20\n20 10\n5 5\n10 30\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.n, 3);
        assert_eq!(g.m, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
