//! Row-block spatial partitioning (§4.1, Fig. 2): node rows are split into
//! P contiguous blocks of NI = N/P rows; shard i owns rows
//! [i*NI, (i+1)*NI). Graphs are padded to the bucket size N first.

/// A spatial partition of a padded N-node graph over P shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    /// Padded node count (bucket size, divisible by 12).
    pub n: usize,
    /// Number of shards ("GPUs").
    pub p: usize,
}

impl Partition {
    /// Build a partition; P must divide the padded N.
    pub fn new(n: usize, p: usize) -> Partition {
        assert!(p >= 1 && n % p == 0, "P={p} must divide padded N={n}");
        Partition { n, p }
    }

    /// Shard height NI = N / P.
    pub fn ni(&self) -> usize {
        self.n / self.p
    }

    /// First row owned by shard i.
    pub fn row0(&self, i: usize) -> usize {
        assert!(i < self.p);
        i * self.ni()
    }

    /// Row range [start, end) owned by shard i.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.row0(i)..self.row0(i) + self.ni()
    }

    /// The shard that owns node v.
    pub fn owner(&self, v: usize) -> usize {
        assert!(v < self.n);
        v / self.ni()
    }

    /// Local row index of node v within its owner shard.
    pub fn local(&self, v: usize) -> usize {
        v % self.ni()
    }

    /// Round `n` up to the next bucket size divisible by `lcm` (12 covers
    /// P ∈ {1,2,3,4,6}).
    pub fn pad_to_bucket(n: usize, lcm: usize) -> usize {
        n.div_ceil(lcm) * lcm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn ranges_tile_rows() {
        let part = Partition::new(24, 4);
        assert_eq!(part.ni(), 6);
        let mut covered = vec![0u8; 24];
        for i in 0..4 {
            for r in part.range(i) {
                covered[r] += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1));
    }

    #[test]
    fn owner_and_local_consistent() {
        let part = Partition::new(24, 3);
        for v in 0..24 {
            let i = part.owner(v);
            assert!(part.range(i).contains(&v));
            assert_eq!(part.row0(i) + part.local(v), v);
        }
    }

    #[test]
    fn pad_to_bucket_rounds_up() {
        assert_eq!(Partition::pad_to_bucket(20, 12), 24);
        assert_eq!(Partition::pad_to_bucket(24, 12), 24);
        assert_eq!(Partition::pad_to_bucket(250, 12), 252);
        assert_eq!(Partition::pad_to_bucket(1, 12), 12);
    }

    #[test]
    #[should_panic]
    fn rejects_nondivisible() {
        Partition::new(25, 4);
    }

    #[test]
    fn prop_partition_is_exact_cover() {
        prop::check(
            "partition-cover",
            50,
            |r| {
                let p = [1, 2, 3, 4, 6][r.gen_range(5)];
                let n = 12 * (1 + r.gen_range(20));
                (n, p)
            },
            |&(n, p)| {
                let part = Partition::new(n, p);
                (0..n).all(|v| part.range(part.owner(v)).contains(&v))
                    && (0..p).map(|i| part.range(i).len()).sum::<usize>() == n
            },
        );
    }
}
