//! Row-block spatial partitioning (§4.1, Fig. 2): node rows are split into
//! P contiguous blocks of NI = N/P rows; shard i owns rows
//! [i*NI, (i+1)*NI). Graphs are padded to the bucket size N first.
//!
//! For paper-scale graphs the partition is *streamed*: `shard_views`
//! yields one zero-copy [`ShardView`] at a time, borrowing each shard's
//! row slice straight out of the host CSR, so a 30M-edge graph partitions
//! shard-by-shard within the DESIGN.md §7 memory model instead of
//! materializing P dense B·NI·N blocks.

use super::csr::Graph;

/// A spatial partition of a padded N-node graph over P shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    /// Padded node count (bucket size, divisible by 12).
    pub n: usize,
    /// Number of shards ("GPUs").
    pub p: usize,
}

impl Partition {
    /// Build a partition; P must divide the padded N.
    pub fn new(n: usize, p: usize) -> Partition {
        assert!(p >= 1 && n % p == 0, "P={p} must divide padded N={n}");
        Partition { n, p }
    }

    /// Shard height NI = N / P.
    pub fn ni(&self) -> usize {
        self.n / self.p
    }

    /// First row owned by shard i.
    pub fn row0(&self, i: usize) -> usize {
        assert!(i < self.p);
        i * self.ni()
    }

    /// Row range [start, end) owned by shard i.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        self.row0(i)..self.row0(i) + self.ni()
    }

    /// The shard that owns node v.
    pub fn owner(&self, v: usize) -> usize {
        assert!(v < self.n);
        v / self.ni()
    }

    /// Local row index of node v within its owner shard.
    pub fn local(&self, v: usize) -> usize {
        v % self.ni()
    }

    /// Round `n` up to the next bucket size divisible by `lcm` (12 covers
    /// P ∈ {1,2,3,4,6}).
    pub fn pad_to_bucket(n: usize, lcm: usize) -> usize {
        n.div_ceil(lcm) * lcm
    }

    /// Stream zero-copy views of `g`'s shards, one per shard in order.
    /// The partition may be padded past `g.n`: trailing views clamp to
    /// the real node count (a shard wholly in padding views zero rows).
    pub fn shard_views<'g>(&self, g: &'g Graph) -> impl Iterator<Item = ShardView<'g>> {
        assert!(g.n <= self.n, "graph n={} exceeds padded N={}", g.n, self.n);
        let part = *self;
        (0..part.p).map(move |i| {
            let row0 = part.row0(i);
            let rows = part.ni().min(g.n.saturating_sub(row0));
            ShardView { shard: i, row0, rows, graph: g }
        })
    }
}

/// A zero-copy CSR view of the rows one shard owns — the streaming
/// partitioning path for paper-scale graphs (DESIGN.md §7). Dense
/// partitioning materializes B·NI·N f32 cells per shard; a `ShardView`
/// borrows the shard's row slice straight out of the host CSR, so
/// walking all P shards keeps resident bytes at the CSR itself plus
/// O(1) per view.
#[derive(Debug, Clone, Copy)]
pub struct ShardView<'g> {
    /// Shard index in [0, P).
    pub shard: usize,
    /// First global row this shard owns.
    pub row0: usize,
    /// Rows actually viewed: min(NI, g.n - row0); padded tail rows past
    /// the real node count hold no edges and are not viewed.
    pub rows: usize,
    graph: &'g Graph,
}

impl<'g> ShardView<'g> {
    /// Neighbors of local row `r` (global column ids, sorted ascending).
    pub fn neighbors(&self, r: usize) -> &'g [u32] {
        assert!(r < self.rows, "local row {r} out of {} viewed rows", self.rows);
        self.graph.neighbors(self.row0 + r)
    }

    /// Directed CSR entries resident in this shard (sum of row degrees).
    pub fn entries(&self) -> usize {
        self.graph.row_ptr[self.row0 + self.rows] - self.graph.row_ptr[self.row0]
    }

    /// Iterate the shard's directed edges as (local row, global column),
    /// row-major with ascending columns — the canonical order
    /// `Graph::shard_edges` produces with no removals, without
    /// materializing its `Vec`.
    pub fn iter_edges(&self) -> impl Iterator<Item = (u32, u32)> + 'g {
        let me = *self;
        (0..me.rows).flat_map(move |r| {
            me.graph.neighbors(me.row0 + r).iter().map(move |&c| (r as u32, c))
        })
    }

    /// Bytes of host CSR this view spans (row offsets + column indices) —
    /// what a per-shard CSR copy would cost. The scale smoke asserts the
    /// sum over all shards stays O(N + E), orders of magnitude under the
    /// dense 4·B·NI·N model of DESIGN.md §7.
    pub fn resident_bytes(&self) -> usize {
        (self.rows + 1) * std::mem::size_of::<usize>()
            + self.entries() * std::mem::size_of::<u32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn ranges_tile_rows() {
        let part = Partition::new(24, 4);
        assert_eq!(part.ni(), 6);
        let mut covered = vec![0u8; 24];
        for i in 0..4 {
            for r in part.range(i) {
                covered[r] += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1));
    }

    #[test]
    fn owner_and_local_consistent() {
        let part = Partition::new(24, 3);
        for v in 0..24 {
            let i = part.owner(v);
            assert!(part.range(i).contains(&v));
            assert_eq!(part.row0(i) + part.local(v), v);
        }
    }

    #[test]
    fn pad_to_bucket_rounds_up() {
        assert_eq!(Partition::pad_to_bucket(20, 12), 24);
        assert_eq!(Partition::pad_to_bucket(24, 12), 24);
        assert_eq!(Partition::pad_to_bucket(250, 12), 252);
        assert_eq!(Partition::pad_to_bucket(1, 12), 12);
    }

    #[test]
    #[should_panic]
    fn rejects_nondivisible() {
        Partition::new(25, 4);
    }

    #[test]
    fn shard_views_match_shard_edges() {
        use crate::graph::generators;
        use crate::util::rng::Pcg32;
        let g = generators::erdos_renyi(30, 0.25, &mut Pcg32::seeded(3));
        let n_pad = Partition::pad_to_bucket(g.n, 12);
        for p in [1usize, 2, 3] {
            let part = Partition::new(n_pad, p);
            let alive = vec![false; g.n];
            let mut total_rows = 0;
            let mut total_entries = 0;
            for view in part.shard_views(&g) {
                assert_eq!(view.row0, part.row0(view.shard));
                let streamed: Vec<(u32, u32)> = view.iter_edges().collect();
                // Canonical order: identical to the compute path's shard
                // edge enumeration with nothing removed.
                assert_eq!(streamed, g.shard_edges(view.row0, view.rows, &alive));
                assert_eq!(view.entries(), streamed.len());
                total_rows += view.rows;
                total_entries += view.entries();
            }
            assert_eq!(total_rows, g.n);
            assert_eq!(total_entries, 2 * g.m, "every directed entry in exactly one shard");
        }
    }

    #[test]
    fn shard_views_clamp_to_padding() {
        use crate::graph::generators;
        use crate::util::rng::Pcg32;
        let g = generators::erdos_renyi(10, 0.4, &mut Pcg32::seeded(4));
        // Padded far past n: the last shards view zero rows.
        let part = Partition::new(24, 4);
        let views: Vec<_> = part.shard_views(&g).collect();
        assert_eq!(views.len(), 4);
        assert_eq!(views[0].rows, 6);
        assert_eq!(views[1].rows, 4); // rows 6..10 of 10
        assert_eq!(views[2].rows, 0);
        assert_eq!(views[3].rows, 0);
        assert_eq!(views[2].entries(), 0);
    }

    #[test]
    fn shard_view_resident_bytes_are_o_of_csr() {
        use crate::graph::generators;
        use crate::util::rng::Pcg32;
        let g = generators::barabasi_albert(120, 4, &mut Pcg32::seeded(5));
        let part = Partition::new(Partition::pad_to_bucket(g.n, 12), 2);
        let total: usize = part.shard_views(&g).map(|v| v.resident_bytes()).sum();
        // Row offsets + column indices, never the dense 4*NI*N block.
        let csr_bytes = (g.n + part.p) * std::mem::size_of::<usize>()
            + 2 * g.m * std::mem::size_of::<u32>();
        assert_eq!(total, csr_bytes);
        assert!(total < 4 * part.ni() * part.n);
    }

    #[test]
    fn prop_partition_is_exact_cover() {
        prop::check(
            "partition-cover",
            50,
            |r| {
                let p = [1, 2, 3, 4, 6][r.gen_range(5)];
                let n = 12 * (1 + r.gen_range(20));
                (n, p)
            },
            |&(n, p)| {
                let part = Partition::new(n, p);
                (0..n).all(|v| part.range(part.owner(v)).contains(&v))
                    && (0..p).map(|i| part.range(i).len()).sum::<usize>() == n
            },
        );
    }
}
