//! Graph substrate: CSR storage, generators, row partitioning, I/O, stats.
//!
//! The paper stores adjacency matrices in sparse COO on each GPU (§4.1 /
//! §5.2); here CSR is the canonical host-side representation (environment
//! logic, replay reconstruction) and dense per-shard f32 tensors are
//! materialized for the XLA compute path (DESIGN.md §3).

/// CSR host graph (the canonical representation).
pub mod csr;
/// COO sparse matrices (paper §5.2 accounting, interop).
pub mod coo;
/// ER / BA / HK graph generators (paper §6.1).
pub mod generators;
/// Row-block spatial partitioning (§4.1, Fig. 2).
pub mod partition;
/// Block-diagonal packing + edge-list offsets (DESIGN.md §4/§7).
pub mod pack;
/// Graph file I/O (SNAP edge lists, MatrixMarket `.mtx`), streaming.
pub mod io;
/// Dataset statistics (Table 1 rows).
pub mod stats;

pub use csr::{CsrBuilder, Graph};
pub use pack::PackLayout;
pub use partition::{Partition, ShardView};
