//! Graph substrate: CSR storage, generators, row partitioning, I/O, stats.
//!
//! The paper stores adjacency matrices in sparse COO on each GPU (§4.1 /
//! §5.2); here CSR is the canonical host-side representation (environment
//! logic, replay reconstruction) and dense per-shard f32 tensors are
//! materialized for the XLA compute path (DESIGN.md §3).

pub mod csr;
pub mod coo;
pub mod generators;
pub mod partition;
pub mod pack;
pub mod io;
pub mod stats;

pub use csr::Graph;
pub use pack::PackLayout;
pub use partition::Partition;
