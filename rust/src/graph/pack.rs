//! Block-diagonal graph packing for graph-level batched processing.
//!
//! B independent graphs are packed into one sharded state whose virtual
//! adjacency is block-diagonal: slot `s` owns the padded row/column block
//! `[s*N, (s+1)*N)` of an (B·N)×(B·N) matrix. Because the off-diagonal
//! blocks are identically zero, the physical realization is the stage batch
//! dimension (`ShardState` stores B×NI×N) — per-slot blocks never interact,
//! which is exactly what makes batched inference per-graph-equivalent to
//! sequential runs. This module owns the id arithmetic: mapping a (slot,
//! local node) pair to its packed id and back, so solutions can be
//! round-tripped out of the pack.

/// The layout of one pack: a common padded bucket size and the per-slot
/// unpadded graph sizes (a size of 0 marks an empty padding slot).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackLayout {
    /// Padded per-graph bucket size N (divisible by the shard lcm).
    pub bucket_n: usize,
    /// Unpadded node count of the graph in each slot.
    pub sizes: Vec<usize>,
}

impl PackLayout {
    /// Build a layout; every slot size must fit the bucket.
    pub fn new(bucket_n: usize, sizes: Vec<usize>) -> PackLayout {
        assert!(bucket_n > 0, "bucket must be positive");
        assert!(
            sizes.iter().all(|&n| n <= bucket_n),
            "a slot's graph exceeds the bucket size {bucket_n}"
        );
        PackLayout { bucket_n, sizes }
    }

    /// Number of slots B in the pack (including empty padding slots).
    pub fn slots(&self) -> usize {
        self.sizes.len()
    }

    /// Total padded node count across the pack (the virtual block-diagonal
    /// matrix is this many rows/columns).
    pub fn total_padded(&self) -> usize {
        self.slots() * self.bucket_n
    }

    /// Packed id of local node `v` of the graph in `slot`.
    pub fn pack_id(&self, slot: usize, v: usize) -> usize {
        assert!(slot < self.slots(), "slot {slot} out of range");
        assert!(v < self.sizes[slot], "node {v} outside slot {slot}'s graph");
        slot * self.bucket_n + v
    }

    /// Inverse of `pack_id`: (slot, local node). Panics on ids that fall in
    /// padding (no graph node lives there).
    pub fn unpack_id(&self, id: usize) -> (usize, usize) {
        let slot = id / self.bucket_n;
        let v = id % self.bucket_n;
        assert!(slot < self.slots(), "packed id {id} out of range");
        assert!(v < self.sizes[slot], "packed id {id} falls in slot {slot}'s padding");
        (slot, v)
    }

    /// The packed-id range holding slot `slot`'s block (including padding).
    pub fn slot_range(&self, slot: usize) -> std::ops::Range<usize> {
        assert!(slot < self.slots());
        slot * self.bucket_n..(slot + 1) * self.bucket_n
    }

    /// Whether a packed id addresses a real graph node (not padding).
    pub fn is_real(&self, id: usize) -> bool {
        let slot = id / self.bucket_n;
        slot < self.slots() && id % self.bucket_n < self.sizes[slot]
    }

    /// Undirected edge count per slot for the graphs occupying this layout
    /// (`graphs[i]` fills slot i; missing trailing slots are empty padding).
    /// On the sparse path the pack's "block-diagonal adjacency" is exactly
    /// the concatenation of these per-slot edge lists — off-diagonal blocks
    /// hold no edges by construction — so the concatenated list plus these
    /// counts fully describes the pack (DESIGN.md §7).
    pub fn edge_counts(&self, graphs: &[&crate::graph::Graph]) -> Vec<usize> {
        assert!(graphs.len() <= self.slots(), "more graphs than slots");
        let mut counts = vec![0usize; self.slots()];
        for (slot, g) in graphs.iter().enumerate() {
            assert_eq!(g.n, self.sizes[slot], "slot {slot} size mismatch");
            counts[slot] = g.m;
        }
        counts
    }

    /// Prefix offsets of the concatenated per-slot edge lists: slot s's
    /// undirected edges occupy [offsets[s], offsets[s+1]) of the
    /// concatenation; the final entry is the pack's total edge count E —
    /// the O(E/P + NI) term of the sparse memory model (DESIGN.md §7).
    pub fn edge_offsets(&self, graphs: &[&crate::graph::Graph]) -> Vec<usize> {
        let counts = self.edge_counts(graphs);
        let mut offsets = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for c in counts {
            acc += c;
            offsets.push(acc);
        }
        offsets
    }

    /// Total undirected edges across the pack (edge_offsets' last entry).
    pub fn total_edges(&self, graphs: &[&crate::graph::Graph]) -> usize {
        graphs.iter().map(|g| g.m).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pack_unpack_roundtrip() {
        let layout = PackLayout::new(24, vec![20, 17, 24, 0, 5]);
        for slot in 0..layout.slots() {
            for v in 0..layout.sizes[slot] {
                let id = layout.pack_id(slot, v);
                assert_eq!(layout.unpack_id(id), (slot, v));
                assert!(layout.is_real(id));
            }
        }
        assert_eq!(layout.total_padded(), 5 * 24);
    }

    #[test]
    fn padding_is_not_real() {
        let layout = PackLayout::new(12, vec![10, 12]);
        assert!(!layout.is_real(10)); // slot 0 padding
        assert!(!layout.is_real(11));
        assert!(layout.is_real(12)); // slot 1 node 0
        assert!(layout.is_real(23));
        assert!(!layout.is_real(24)); // past the pack
        // Empty slot: nothing is real in its whole block.
        let e = PackLayout::new(12, vec![0, 3]);
        assert!((0..12).all(|id| !e.is_real(id)));
    }

    #[test]
    fn slot_ranges_tile_the_pack() {
        let layout = PackLayout::new(24, vec![20, 24, 8]);
        let mut covered = vec![0u8; layout.total_padded()];
        for slot in 0..layout.slots() {
            for id in layout.slot_range(slot) {
                covered[id] += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1));
    }

    #[test]
    #[should_panic(expected = "padding")]
    fn unpack_rejects_padding_ids() {
        PackLayout::new(24, vec![20]).unpack_id(21);
    }

    #[test]
    #[should_panic(expected = "exceeds the bucket")]
    fn rejects_oversized_slot() {
        PackLayout::new(12, vec![13]);
    }

    #[test]
    fn edge_offsets_concatenate_slot_lists() {
        use crate::graph::Graph;
        let g1 = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        let g2 = Graph::from_edges(3, &[(0, 2)]).unwrap();
        let layout = PackLayout::new(12, vec![4, 3, 0]);
        let refs: Vec<&Graph> = vec![&g1, &g2];
        assert_eq!(layout.edge_counts(&refs), vec![3, 1, 0]);
        assert_eq!(layout.edge_offsets(&refs), vec![0, 3, 4, 4]);
        assert_eq!(layout.total_edges(&refs), 4);
    }

    #[test]
    fn prop_roundtrip_all_layouts() {
        prop::check(
            "pack-roundtrip",
            50,
            |r| {
                let bucket = 12 * (1 + r.gen_range(4));
                let slots = 1 + r.gen_range(8);
                let sizes: Vec<usize> =
                    (0..slots).map(|_| r.gen_range(bucket + 1)).collect();
                (bucket, sizes)
            },
            |(bucket, sizes)| {
                let layout = PackLayout::new(*bucket, sizes.clone());
                (0..layout.slots()).all(|s| {
                    (0..layout.sizes[s]).all(|v| {
                        let id = layout.pack_id(s, v);
                        layout.unpack_id(id) == (s, v)
                            && layout.slot_range(s).contains(&id)
                            && layout.is_real(id)
                    })
                })
            },
        );
    }
}
