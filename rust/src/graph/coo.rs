//! Sparse COO (coordinate) adjacency storage — the paper's on-GPU format
//! (§5.2: `torch.sparse.FloatTensor`, 20 bytes per nonzero). Used for
//! import/export interop and for validating the §5.2 memory model against
//! actual structures; the compute path densifies per shard (DESIGN.md §3).

use super::csr::Graph;

/// A COO sparse matrix over the directed expansion of an undirected graph
/// (each undirected edge appears twice, like the paper's adjacency).
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    /// Number of matrix rows (shard height for shard COO).
    pub n_rows: usize,
    /// Number of matrix columns (global node count).
    pub n_cols: usize,
    /// Row index per nonzero.
    pub rows: Vec<u32>,
    /// Column index per nonzero.
    pub cols: Vec<u32>,
    /// Value per nonzero (1.0 for adjacency).
    pub vals: Vec<f32>,
}

impl Coo {
    /// Full adjacency of `g` in COO (2m nonzeros).
    pub fn from_graph(g: &Graph) -> Coo {
        let mut rows = Vec::with_capacity(2 * g.m);
        let mut cols = Vec::with_capacity(2 * g.m);
        for u in 0..g.n {
            for &v in g.neighbors(u) {
                rows.push(u as u32);
                cols.push(v);
            }
        }
        let nnz = rows.len();
        Coo { n_rows: g.n, n_cols: g.n, rows, cols, vals: vec![1.0; nnz] }
    }

    /// One shard's row block [row0, row0+rows) as COO (the paper's
    /// distributed storage unit, Fig. 2).
    pub fn shard_from_graph(g: &Graph, row0: usize, rows_count: usize) -> Coo {
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        for r in 0..rows_count {
            let v = row0 + r;
            if v >= g.n {
                continue;
            }
            for &u in g.neighbors(v) {
                rows.push(r as u32);
                cols.push(u);
            }
        }
        let nnz = rows.len();
        Coo { n_rows: rows_count, n_cols: g.n, rows, cols, vals: vec![1.0; nnz] }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Bytes under the paper's accounting: 20 bytes per nonzero
    /// (2× int64 index + f32 value, §5.2).
    pub fn bytes_paper(&self) -> usize {
        20 * self.nnz()
    }

    /// Bytes of this implementation (u32 indices + f32 values).
    pub fn bytes_actual(&self) -> usize {
        12 * self.nnz()
    }

    /// Densify into row-major f32 (for parity tests against `densify_rows`).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_rows * self.n_cols];
        for i in 0..self.nnz() {
            out[self.rows[i] as usize * self.n_cols + self.cols[i] as usize] = self.vals[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;

    #[test]
    fn full_coo_counts() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        let c = Coo::from_graph(&g);
        assert_eq!(c.nnz(), 4);
        assert_eq!(c.bytes_paper(), 80);
        assert_eq!(c.bytes_actual(), 48);
    }

    #[test]
    fn prop_shard_coo_matches_densify() {
        prop::check_msg(
            "coo-shard-vs-dense",
            15,
            |r| {
                let n = 8 + r.gen_range(40);
                (generators::erdos_renyi(n, 0.25, r), r.gen_range(4) + 1)
            },
            |(g, p)| {
                // Compare COO shard densification against Graph::densify_rows
                // over p row blocks covering the graph (padded).
                let padded = g.n.div_ceil(*p) * p;
                let rows = padded / p;
                for shard in 0..*p {
                    let row0 = shard * rows;
                    let coo = Coo::shard_from_graph(g, row0, rows);
                    let mut want = vec![0.0f32; rows * g.n];
                    g.densify_rows(row0, rows, g.n, &vec![false; g.n], &mut want);
                    if coo.to_dense() != want {
                        return Err(format!("shard {shard} mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shard_blocks_partition_nnz() {
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        let g = generators::erdos_renyi(48, 0.2, &mut rng);
        let full = Coo::from_graph(&g).nnz();
        let total: usize =
            (0..4).map(|s| Coo::shard_from_graph(&g, s * 12, 12).nnz()).sum();
        assert_eq!(total, full);
    }
}
