//! Graph generators: Erdős–Rényi, Barabási–Albert, Holme–Kim.
//!
//! ER and BA match the paper's §6.1 dataset models (`ER(n, ρ)` with ρ=0.15,
//! `BA(n, d)` with d=4). Holme–Kim (powerlaw-cluster: BA growth + triad
//! closure) generates the "social network" stand-ins for the Facebook
//! university graphs of Table 1 (DESIGN.md §3 substitution).

use super::csr::{CsrBuilder, Graph};
use crate::util::rng::Pcg32;

/// Erdős–Rényi G(n, rho): each pair independently connected with prob rho.
pub fn erdos_renyi(n: usize, rho: f64, rng: &mut Pcg32) -> Graph {
    let mut edges = Vec::new();
    // Geometric skipping (Batagelj–Brandes) keeps generation O(m).
    let ln_q = (1.0 - rho).ln();
    if rho >= 1.0 {
        for u in 0..n as u32 {
            for v in (u + 1)..n as u32 {
                edges.push((u, v));
            }
        }
        return Graph::from_edges(n, &edges).unwrap();
    }
    if rho > 0.0 {
        let (mut u, mut v) = (1i64, -1i64);
        while (u as usize) < n {
            let r = rng.next_f64().max(1e-300);
            v += 1 + (r.ln() / ln_q) as i64;
            while v >= u && (u as usize) < n {
                v -= u;
                u += 1;
            }
            if (u as usize) < n {
                edges.push((v as u32, u as u32));
            }
        }
    }
    Graph::from_edges(n, &edges).unwrap()
}

/// Barabási–Albert BA(n, d): preferential attachment, d edges per new node.
pub fn barabasi_albert(n: usize, d: usize, rng: &mut Pcg32) -> Graph {
    assert!(n > d && d >= 1, "BA requires n > d >= 1");
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * d);
    // `targets` holds one entry per edge endpoint: sampling uniformly from
    // it implements degree-proportional attachment.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * d);
    // Seed: star over the first d+1 nodes keeps the graph connected.
    for v in 0..d as u32 {
        edges.push((v, d as u32));
        endpoints.push(v);
        endpoints.push(d as u32);
    }
    for u in (d + 1)..n {
        // Insertion-ordered Vec keeps generation deterministic per seed
        // (d is small, linear `contains` is fine).
        let mut picked: Vec<u32> = Vec::with_capacity(d);
        while picked.len() < d {
            let t = endpoints[rng.gen_range(endpoints.len())];
            if !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            edges.push((t, u as u32));
            endpoints.push(t);
            endpoints.push(u as u32);
        }
    }
    Graph::from_edges(n, &edges).unwrap()
}

/// Holme–Kim powerlaw-cluster graph: BA(n, d) growth where each attachment
/// is followed with probability `p_triad` by a triad-closure step (connect
/// to a random neighbor of the last target). Produces the heavy-tailed,
/// clustered structure of social networks.
pub fn holme_kim(n: usize, d: usize, p_triad: f64, rng: &mut Pcg32) -> Graph {
    assert!(n > d && d >= 1);
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut endpoints: Vec<u32> = Vec::new();
    let add = |adj: &mut Vec<Vec<u32>>, endpoints: &mut Vec<u32>, u: u32, v: u32| {
        adj[u as usize].push(v);
        adj[v as usize].push(u);
        endpoints.push(u);
        endpoints.push(v);
    };
    for v in 0..d as u32 {
        add(&mut adj, &mut endpoints, v, d as u32);
    }
    for u in (d + 1)..n {
        let mut last_target: Option<u32> = None;
        let mut added = 0usize;
        while added < d {
            // Triad closure after a successful preferential step.
            let candidate = if let (Some(t), true) =
                (last_target, rng.next_f64() < p_triad)
            {
                let nbrs = &adj[t as usize];
                let w = nbrs[rng.gen_range(nbrs.len())];
                if w as usize != u && !adj[u].contains(&w) { Some(w) } else { None }
            } else {
                None
            };
            let target = candidate.unwrap_or_else(|| {
                loop {
                    let t = endpoints[rng.gen_range(endpoints.len())];
                    if t as usize != u && !adj[u].contains(&t) {
                        break t;
                    }
                }
            });
            add(&mut adj, &mut endpoints, target, u as u32);
            last_target = Some(target);
            added += 1;
        }
    }
    let mut edges = Vec::new();
    for (u, nbrs) in adj.iter().enumerate() {
        for &v in nbrs {
            if (u as u32) < v {
                edges.push((u as u32, v));
            }
        }
    }
    Graph::from_edges(n, &edges).unwrap()
}

/// R-MAT recursive-matrix generator (Chakrabarti et al. 2004) with the
/// Graph500 quadrant probabilities a=0.57, b=0.19, c=0.19, d=0.05: the
/// standard scale-free model for paper-scale synthetic graphs. Samples
/// `edge_factor * 2^scale` endpoint pairs by recursive quadrant descent,
/// then builds CSR through the streaming [`CsrBuilder`] — self-loops are
/// dropped and duplicates deduplicated, so the final edge count is
/// slightly below `edge_factor * 2^scale` (more so at high skew). Nodes
/// never hit by an edge stay as isolated vertices of the 2^scale-node
/// graph.
pub fn rmat(scale: u32, edge_factor: usize, rng: &mut Pcg32) -> Graph {
    assert!(scale >= 1 && scale < 32, "rmat scale must be in [1, 31]");
    let n = 1usize << scale;
    let target = n * edge_factor;
    const A: f64 = 0.57;
    const B: f64 = 0.19;
    const C: f64 = 0.19;
    let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(target);
    for _ in 0..target {
        let (mut u, mut v) = (0u32, 0u32);
        for _ in 0..scale {
            let r = rng.next_f64();
            let (bu, bv) = if r < A {
                (0, 0)
            } else if r < A + B {
                (0, 1)
            } else if r < A + B + C {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u != v {
            pairs.push((u, v));
        }
    }
    // Two passes over the sampled pairs — no global sort, no Vec<Vec>.
    let mut bld = CsrBuilder::new(n);
    for &(u, v) in &pairs {
        bld.count(u, v).expect("rmat endpoints are in range by construction");
    }
    bld.begin_fill();
    for &(u, v) in &pairs {
        bld.fill(u, v).expect("fill replays the count pass");
    }
    bld.finish().expect("rmat pairs are loop-free and symmetric")
}

/// The paper's generated-dataset defaults (§6.1).
pub const ER_RHO: f64 = 0.15;
/// Barabási–Albert attachment degree default (paper §6.1).
pub const BA_D: usize = 4;

/// Table 1 stand-in datasets (¼-scale Facebook university networks).
/// d chosen so that the edge probability matches the paper's reported rho.
pub fn social_standins(rng: &mut Pcg32) -> Vec<(&'static str, Graph)> {
    // paper: Vanderbilt |V|=8.1K rho=.0131; Georgetown 9.4K .0096;
    // Mississippi 10.5K .0110. Quarter scale: n/4, d = rho*n/8 (approx m = n*d).
    vec![
        ("vanderbilt-q", holme_kim(2028, 13, 0.25, rng)),
        ("georgetown-q", holme_kim(2352, 11, 0.25, rng)),
        ("mississippi-q", holme_kim(2628, 14, 0.25, rng)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn er_density_close_to_rho() {
        let mut rng = Pcg32::seeded(1);
        let g = erdos_renyi(400, 0.15, &mut rng);
        let rho = g.edge_probability();
        assert!((rho - 0.15).abs() < 0.02, "rho {rho}");
    }

    #[test]
    fn er_extremes() {
        let mut rng = Pcg32::seeded(2);
        assert_eq!(erdos_renyi(50, 0.0, &mut rng).m, 0);
        assert_eq!(erdos_renyi(10, 1.0, &mut rng).m, 45);
    }

    #[test]
    fn ba_edge_count() {
        let mut rng = Pcg32::seeded(3);
        let (n, d) = (200, 4);
        let g = barabasi_albert(n, d, &mut rng);
        assert_eq!(g.m, d + (n - d - 1) * d);
    }

    #[test]
    fn ba_heavy_tail() {
        let mut rng = Pcg32::seeded(4);
        let g = barabasi_albert(500, 4, &mut rng);
        let dmax = (0..g.n).map(|v| g.degree(v)).max().unwrap();
        let mean = 2.0 * g.m as f64 / g.n as f64;
        assert!(dmax as f64 > 4.0 * mean, "dmax {dmax} vs mean {mean}");
    }

    #[test]
    fn holme_kim_clusters_more_than_ba() {
        let mut rng = Pcg32::seeded(5);
        let hk = holme_kim(400, 4, 0.6, &mut rng);
        let ba = barabasi_albert(400, 4, &mut rng);
        let c_hk = super::super::stats::clustering_coefficient(&hk, 200, &mut rng);
        let c_ba = super::super::stats::clustering_coefficient(&ba, 200, &mut rng);
        assert!(c_hk > c_ba, "clustering hk={c_hk} ba={c_ba}");
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let g1 = erdos_renyi(100, 0.1, &mut Pcg32::seeded(7));
        let g2 = erdos_renyi(100, 0.1, &mut Pcg32::seeded(7));
        assert_eq!(g1, g2);
        let b1 = barabasi_albert(100, 3, &mut Pcg32::seeded(7));
        let b2 = barabasi_albert(100, 3, &mut Pcg32::seeded(7));
        assert_eq!(b1, b2);
    }

    #[test]
    fn rmat_is_simple_and_skewed() {
        let mut rng = Pcg32::seeded(21);
        let g = rmat(10, 8, &mut rng);
        assert_eq!(g.n, 1024);
        // Dedup and loop-dropping shave a chunk of the 8192 sampled pairs
        // (hub pairs repeat often at this small scale).
        assert!(g.m > 3000 && g.m <= 8192, "m={}", g.m);
        assert_eq!(g.row_ptr[g.n], 2 * g.m);
        assert!((0..g.n).all(|v| g.neighbors(v).iter().all(|&u| (u as usize) != v)));
        assert!((0..g.n).all(|v| g.neighbors(v).windows(2).all(|w| w[0] < w[1])));
        // Quadrant skew concentrates degree mass far above the mean.
        let dmax = (0..g.n).map(|v| g.degree(v)).max().unwrap();
        let mean = 2.0 * g.m as f64 / g.n as f64;
        assert!(dmax as f64 > 4.0 * mean, "dmax {dmax} vs mean {mean}");
    }

    #[test]
    fn rmat_deterministic_per_seed() {
        let g1 = rmat(8, 4, &mut Pcg32::seeded(9));
        let g2 = rmat(8, 4, &mut Pcg32::seeded(9));
        assert_eq!(g1, g2);
    }

    #[test]
    fn prop_er_graphs_are_simple() {
        prop::check(
            "er-simple",
            20,
            |r| {
                let n = 10 + r.gen_range(60);
                let rho = r.next_f64() * 0.4;
                erdos_renyi(n, rho, r)
            },
            |g| {
                // CSR builder enforces simplicity; re-validate degrees sum.
                g.row_ptr[g.n] == 2 * g.m
                    && (0..g.n).all(|v| g.neighbors(v).iter().all(|&u| (u as usize) != v))
            },
        );
    }
}
