//! Minimum Vertex Cover environment (§4, the paper's driving problem).
//!
//! State: partial solution S, candidate set C (= unselected nodes that still
//! have uncovered incident edges), residual adjacency (selected nodes'
//! rows/columns removed, Fig. 4). Action: select a candidate node. Reward:
//! -1 per selected node (minimization). Done: every edge covered.

use super::GraphEnv;
use crate::graph::Graph;

#[derive(Debug, Clone)]
/// Minimum Vertex Cover environment (Fig. 1's reference scenario).
pub struct MvcEnv {
    /// The instance being solved.
    pub graph: Graph,
    in_solution: Vec<bool>,
    /// Count of *uncovered* edges incident to each node.
    uncovered_deg: Vec<usize>,
    uncovered_total: usize,
}

impl MvcEnv {
    /// Fresh environment over `graph`.
    pub fn new(graph: Graph) -> MvcEnv {
        let uncovered_deg: Vec<usize> = (0..graph.n).map(|v| graph.degree(v)).collect();
        let uncovered_total = graph.m;
        MvcEnv {
            in_solution: vec![false; graph.n],
            uncovered_deg,
            uncovered_total,
            graph,
        }
    }

    /// Edges not yet covered by the partial solution.
    pub fn uncovered_edges(&self) -> usize {
        self.uncovered_total
    }

    /// Verify a full cover (every edge has a selected endpoint).
    /// Delegates to the canonical streaming checker in `solvers::verify`.
    pub fn is_vertex_cover(graph: &Graph, sol: &[bool]) -> bool {
        crate::solvers::verify::is_vertex_cover(graph, sol)
    }
}

impl GraphEnv for MvcEnv {
    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn step(&mut self, v: usize) -> (f32, bool) {
        assert!(self.is_candidate(v), "node {v} is not a candidate");
        self.in_solution[v] = true;
        // Cover v's uncovered incident edges.
        for &u in self.graph.neighbors(v) {
            let u = u as usize;
            if !self.in_solution[u] {
                self.uncovered_deg[u] -= 1;
                self.uncovered_total -= 1;
            }
        }
        self.uncovered_deg[v] = 0;
        (-1.0, self.done())
    }

    fn is_candidate(&self, v: usize) -> bool {
        v < self.graph.n && !self.in_solution[v] && self.uncovered_deg[v] > 0
    }

    fn solution_mask(&self) -> &[bool] {
        &self.in_solution
    }

    fn removed_mask(&self) -> &[bool] {
        // For MVC, selected nodes leave the residual graph (Fig. 4's zeroed
        // row/column): removed == in_solution.
        &self.in_solution
    }

    fn done(&self) -> bool {
        self.uncovered_total == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn path4() -> Graph {
        Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap()
    }

    #[test]
    fn rewards_and_done() {
        let mut env = MvcEnv::new(path4());
        assert!(!env.done());
        let (r, done) = env.step(1);
        assert_eq!(r, -1.0);
        assert!(!done);
        assert_eq!(env.uncovered_edges(), 1);
        let (r, done) = env.step(2);
        assert_eq!(r, -1.0);
        assert!(done);
        assert!(MvcEnv::is_vertex_cover(&env.graph, env.solution_mask()));
    }

    #[test]
    fn candidates_shrink() {
        let mut env = MvcEnv::new(path4());
        assert!(env.is_candidate(0));
        env.step(1);
        // Node 0's only edge is now covered: no longer a candidate.
        assert!(!env.is_candidate(0));
        assert!(!env.is_candidate(1)); // in solution
        assert!(env.is_candidate(2));
    }

    #[test]
    #[should_panic(expected = "not a candidate")]
    fn rejects_non_candidate() {
        let mut env = MvcEnv::new(path4());
        env.step(1);
        env.step(0);
    }

    #[test]
    fn isolated_nodes_never_candidates() {
        let g = Graph::from_edges(3, &[(0, 1)]).unwrap();
        let env = MvcEnv::new(g);
        assert!(!env.is_candidate(2));
    }

    #[test]
    fn prop_episode_terminates_with_valid_cover() {
        prop::check_msg(
            "mvc-episode",
            25,
            |r| {
                let n = 8 + r.gen_range(40);
                (generators::erdos_renyi(n, 0.2, r), r.next_u64())
            },
            |(g, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                let mut env = MvcEnv::new(g.clone());
                let mut steps = 0usize;
                while !env.done() {
                    let cands: Vec<usize> =
                        (0..g.n).filter(|&v| env.is_candidate(v)).collect();
                    if cands.is_empty() {
                        return Err("no candidates but not done".into());
                    }
                    env.step(cands[rng.gen_range(cands.len())]);
                    steps += 1;
                    if steps > g.n {
                        return Err("episode exceeded |V| steps".into());
                    }
                }
                if !MvcEnv::is_vertex_cover(g, env.solution_mask()) {
                    return Err("final solution is not a cover".into());
                }
                // Reward total == -|S|
                if env.solution_size() != steps {
                    return Err("solution size != steps".into());
                }
                Ok(())
            },
        );
    }
}
