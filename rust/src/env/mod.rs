//! Graph learning environments (the paper's Graph Learning Environment
//! module, Fig. 1): apply an action (node selection), return reward and
//! termination, maintain the candidate set.
//!
//! Environments run on the host (CPU) exactly as in Alg. 5; the per-shard
//! tensor state (`A^i`, `C^i`, `S^i`) lives in `coordinator::shard` and is
//! updated in lockstep with the environment.

/// Minimum Vertex Cover environment (the paper's driving problem).
pub mod mvc;
/// Maximum Cut environment.
pub mod maxcut;
/// Maximum Independent Set environment.
pub mod mis;

pub use mvc::MvcEnv;
pub use maxcut::MaxCutEnv;
pub use mis::MisEnv;

use crate::graph::Graph;
use anyhow::bail;

/// A graph optimization environment over node-selection actions.
pub trait GraphEnv {
    /// The underlying (unpadded) graph instance.
    fn graph(&self) -> &Graph;

    /// Number of nodes of the underlying (unpadded) graph.
    fn num_nodes(&self) -> usize {
        self.graph().n
    }

    /// Apply action `v` (select node v). Returns (reward, done).
    fn step(&mut self, v: usize) -> (f32, bool);

    /// Whether node v is currently a valid candidate action.
    fn is_candidate(&self, v: usize) -> bool;

    /// Current partial solution as a 0/1 vector over nodes.
    fn solution_mask(&self) -> &[bool];

    /// Nodes no longer participating in the residual graph (for MVC these
    /// are the selected nodes; their rows/cols are zeroed per Fig. 4).
    fn removed_mask(&self) -> &[bool];

    /// True when a complete solution has been reached.
    fn done(&self) -> bool;

    /// Size of the current partial solution.
    fn solution_size(&self) -> usize {
        self.solution_mask().iter().filter(|&&b| b).count()
    }

    /// Scenario-specific objective value of the current solution (defaults
    /// to the solution size; MaxCut reports the cut weight instead).
    fn objective(&self) -> f64 {
        self.solution_size() as f64
    }
}

/// The problem scenarios the solve engines can run. Each scenario shares
/// the same node-selection action space and policy model; only the
/// environment semantics differ (Fig. 1's pluggable-environment point).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Scenario {
    /// Minimum Vertex Cover (the paper's driving problem).
    Mvc,
    /// Maximum Cut (greedy-termination convention).
    MaxCut,
    /// Maximum Independent Set.
    Mis,
}

impl Scenario {
    /// All scenarios, in `Ord` order — the (scenario, bucket) grouping
    /// order of the batch queue and the service's open packs.
    pub const ALL: [Scenario; 3] = [Scenario::Mvc, Scenario::MaxCut, Scenario::Mis];

    /// Parse a scenario name (`mvc` | `maxcut` | `mis`).
    pub fn parse(s: &str) -> anyhow::Result<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "mvc" => Ok(Scenario::Mvc),
            "maxcut" | "max-cut" => Ok(Scenario::MaxCut),
            "mis" => Ok(Scenario::Mis),
            other => bail!("unknown scenario '{other}' (mvc|maxcut|mis)"),
        }
    }

    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Scenario::Mvc => "mvc",
            Scenario::MaxCut => "maxcut",
            Scenario::Mis => "mis",
        }
    }

    /// Instantiate the environment for `g`.
    pub fn make_env(self, g: Graph) -> Box<dyn GraphEnv> {
        match self {
            Scenario::Mvc => Box::new(MvcEnv::new(g)),
            Scenario::MaxCut => Box::new(MaxCutEnv::new(g)),
            Scenario::Mis => Box::new(MisEnv::new(g)),
        }
    }

    /// Whether `sol` is a structurally valid complete solution for `g`
    /// (MVC: a vertex cover; MIS: an independent set; MaxCut: any subset).
    pub fn validate(self, g: &Graph, sol: &[bool]) -> bool {
        match self {
            Scenario::Mvc => MvcEnv::is_vertex_cover(g, sol),
            Scenario::MaxCut => true,
            Scenario::Mis => MisEnv::is_independent_set(g, sol),
        }
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_parse_roundtrip() {
        for s in [Scenario::Mvc, Scenario::MaxCut, Scenario::Mis] {
            assert_eq!(Scenario::parse(s.name()).unwrap(), s);
        }
        assert_eq!(Scenario::parse("MaxCut").unwrap(), Scenario::MaxCut);
        assert!(Scenario::parse("tsp").is_err());
    }

    #[test]
    fn make_env_dispatches() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        let mut env = Scenario::Mvc.make_env(g.clone());
        assert_eq!(env.num_nodes(), 3);
        env.step(1);
        assert!(env.done());
        assert!(Scenario::Mvc.validate(&g, env.solution_mask()));

        let mis = Scenario::Mis.make_env(g.clone());
        assert!(mis.is_candidate(0));
        assert_eq!(mis.objective(), 0.0);
    }
}
