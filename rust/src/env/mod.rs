//! Graph learning environments (the paper's Graph Learning Environment
//! module, Fig. 1): apply an action (node selection), return reward and
//! termination, maintain the candidate set.
//!
//! Environments run on the host (CPU) exactly as in Alg. 5; the per-shard
//! tensor state (`A^i`, `C^i`, `S^i`) lives in `coordinator::shard` and is
//! updated in lockstep with the environment.

pub mod mvc;
pub mod maxcut;

pub use mvc::MvcEnv;
pub use maxcut::MaxCutEnv;

/// A graph optimization environment over node-selection actions.
pub trait GraphEnv {
    /// Number of nodes of the underlying (unpadded) graph.
    fn num_nodes(&self) -> usize;

    /// Apply action `v` (select node v). Returns (reward, done).
    fn step(&mut self, v: usize) -> (f32, bool);

    /// Whether node v is currently a valid candidate action.
    fn is_candidate(&self, v: usize) -> bool;

    /// Current partial solution as a 0/1 vector over nodes.
    fn solution_mask(&self) -> &[bool];

    /// Nodes no longer participating in the residual graph (for MVC these
    /// are the selected nodes; their rows/cols are zeroed per Fig. 4).
    fn removed_mask(&self) -> &[bool];

    /// True when a complete solution has been reached.
    fn done(&self) -> bool;

    /// Size of the current partial solution.
    fn solution_size(&self) -> usize {
        self.solution_mask().iter().filter(|&&b| b).count()
    }
}
