//! Maximum Independent Set environment — third scenario for the batched
//! solve engine (Fig. 1's pluggable-environment point, like MaxCut).
//!
//! State: independent set S, residual graph with selected nodes *and their
//! neighbors* removed (selecting v forecloses its whole neighborhood, so the
//! residual update zeroes the closed neighborhood's rows/columns). Action:
//! select any surviving node. Reward: +1 per selected node (maximization).
//! Done: the residual graph is empty — the set is then maximal by
//! construction, and isolated nodes are candidates too (they always belong
//! to some maximum independent set).

use super::GraphEnv;
use crate::graph::Graph;

#[derive(Debug, Clone)]
/// Maximum Independent Set environment.
pub struct MisEnv {
    /// The instance being solved.
    pub graph: Graph,
    in_set: Vec<bool>,
    /// Selected nodes plus their neighbors (dropped from the residual graph).
    removed: Vec<bool>,
    remaining: usize,
}

impl MisEnv {
    /// Fresh environment over `graph`.
    pub fn new(graph: Graph) -> MisEnv {
        MisEnv {
            in_set: vec![false; graph.n],
            removed: vec![false; graph.n],
            remaining: graph.n,
            graph,
        }
    }

    /// Nodes still in the residual graph.
    pub fn remaining_nodes(&self) -> usize {
        self.remaining
    }

    /// Verify independence: no edge with both endpoints selected.
    /// Delegates to the canonical streaming checker in `solvers::verify`.
    pub fn is_independent_set(graph: &Graph, sol: &[bool]) -> bool {
        crate::solvers::verify::is_independent_set(graph, sol)
    }

    /// Verify maximality: every unselected node has a selected neighbor
    /// (no node can be added without breaking independence).
    pub fn is_maximal(graph: &Graph, sol: &[bool]) -> bool {
        (0..graph.n).all(|v| {
            sol[v] || graph.neighbors(v).iter().any(|&u| sol[u as usize])
        })
    }
}

impl GraphEnv for MisEnv {
    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn step(&mut self, v: usize) -> (f32, bool) {
        assert!(self.is_candidate(v), "node {v} is not a candidate");
        self.in_set[v] = true;
        self.removed[v] = true;
        self.remaining -= 1;
        for &u in self.graph.neighbors(v) {
            let u = u as usize;
            if !self.removed[u] {
                self.removed[u] = true;
                self.remaining -= 1;
            }
        }
        (1.0, self.done())
    }

    fn is_candidate(&self, v: usize) -> bool {
        v < self.graph.n && !self.removed[v]
    }

    fn solution_mask(&self) -> &[bool] {
        &self.in_set
    }

    fn removed_mask(&self) -> &[bool] {
        &self.removed
    }

    fn done(&self) -> bool {
        self.remaining == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn path4() -> Graph {
        Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap()
    }

    #[test]
    fn selecting_removes_closed_neighborhood() {
        let mut env = MisEnv::new(path4());
        assert_eq!(env.remaining_nodes(), 4);
        let (r, done) = env.step(1);
        assert_eq!(r, 1.0);
        assert!(!done);
        // 0, 1, 2 removed; only 3 survives.
        assert!(!env.is_candidate(0));
        assert!(!env.is_candidate(2));
        assert!(env.is_candidate(3));
        let (r, done) = env.step(3);
        assert_eq!(r, 1.0);
        assert!(done);
        assert!(MisEnv::is_independent_set(&env.graph, env.solution_mask()));
        assert!(MisEnv::is_maximal(&env.graph, env.solution_mask()));
        assert_eq!(env.solution_size(), 2);
    }

    #[test]
    fn isolated_nodes_are_candidates() {
        let g = Graph::from_edges(3, &[(0, 1)]).unwrap();
        let mut env = MisEnv::new(g);
        assert!(env.is_candidate(2));
        env.step(2);
        assert!(!env.done());
        env.step(0);
        assert!(env.done());
        assert_eq!(env.solution_size(), 2);
    }

    #[test]
    #[should_panic(expected = "not a candidate")]
    fn rejects_removed_node() {
        let mut env = MisEnv::new(path4());
        env.step(1);
        env.step(0); // removed as a neighbor of 1
    }

    #[test]
    fn prop_episode_yields_maximal_independent_set() {
        prop::check_msg(
            "mis-episode",
            25,
            |r| {
                let n = 8 + r.gen_range(40);
                (generators::erdos_renyi(n, 0.2, r), r.next_u64())
            },
            |(g, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                let mut env = MisEnv::new(g.clone());
                let mut steps = 0usize;
                while !env.done() {
                    let cands: Vec<usize> =
                        (0..g.n).filter(|&v| env.is_candidate(v)).collect();
                    if cands.is_empty() {
                        return Err("no candidates but not done".into());
                    }
                    env.step(cands[rng.gen_range(cands.len())]);
                    steps += 1;
                    if steps > g.n {
                        return Err("episode exceeded |V| steps".into());
                    }
                }
                if !MisEnv::is_independent_set(g, env.solution_mask()) {
                    return Err("final solution is not independent".into());
                }
                if !MisEnv::is_maximal(g, env.solution_mask()) {
                    return Err("final solution is not maximal".into());
                }
                if env.solution_size() != steps {
                    return Err("solution size != steps".into());
                }
                Ok(())
            },
        );
    }
}
