//! Maximum Cut environment — the extensibility demo (Fig. 1: "users can add
//! new graph problem environments"). Same node-selection action space and
//! policy model as MVC; the reward is the cut-weight delta of moving the
//! selected node into the cut set, and an episode ends when no move can
//! improve the cut (the ECO-DQN-style greedy-termination convention).

use super::GraphEnv;
use crate::graph::Graph;

#[derive(Debug, Clone)]
/// Maximum Cut environment (greedy-termination convention).
pub struct MaxCutEnv {
    /// The instance being solved.
    pub graph: Graph,
    in_cut: Vec<bool>,
    /// Nodes stay in the residual compute graph for MaxCut (no row removal).
    removed: Vec<bool>,
    cut_value: i64,
}

impl MaxCutEnv {
    /// Fresh environment over `graph`.
    pub fn new(graph: Graph) -> MaxCutEnv {
        MaxCutEnv {
            in_cut: vec![false; graph.n],
            removed: vec![false; graph.n],
            cut_value: 0,
            graph,
        }
    }

    /// Cut gain of toggling v into the cut set: (# neighbors outside cut
    /// after move) - (# neighbors inside... ) — for adding v: edges to
    /// non-cut neighbors become cut, edges to cut neighbors stop being cut.
    pub fn gain(&self, v: usize) -> i64 {
        let mut g = 0i64;
        for &u in self.graph.neighbors(v) {
            if self.in_cut[u as usize] {
                g -= 1;
            } else {
                g += 1;
            }
        }
        g
    }

    /// Current cut weight (incrementally maintained).
    pub fn cut_value(&self) -> i64 {
        self.cut_value
    }

    /// Exact cut value from scratch (test oracle). Delegates to the
    /// canonical streaming checker in `solvers::verify`.
    pub fn compute_cut(graph: &Graph, in_cut: &[bool]) -> i64 {
        crate::solvers::verify::cut_value(graph, in_cut)
    }
}

impl GraphEnv for MaxCutEnv {
    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn step(&mut self, v: usize) -> (f32, bool) {
        assert!(self.is_candidate(v), "node {v} is not a candidate");
        let delta = self.gain(v);
        self.in_cut[v] = true;
        self.cut_value += delta;
        (delta as f32, self.done())
    }

    fn is_candidate(&self, v: usize) -> bool {
        v < self.graph.n && !self.in_cut[v] && self.graph.degree(v) > 0
    }

    fn solution_mask(&self) -> &[bool] {
        &self.in_cut
    }

    fn removed_mask(&self) -> &[bool] {
        &self.removed
    }

    fn done(&self) -> bool {
        // Terminate when no candidate addition improves the cut.
        !(0..self.graph.n).any(|v| self.is_candidate(v) && self.gain(v) > 0)
    }

    fn objective(&self) -> f64 {
        self.cut_value as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn gain_and_cut_track() {
        // Square: 0-1-2-3-0.
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]).unwrap();
        let mut env = MaxCutEnv::new(g);
        assert_eq!(env.gain(0), 2);
        let (r, _) = env.step(0);
        assert_eq!(r, 2.0);
        assert_eq!(env.cut_value(), 2);
        assert_eq!(env.gain(2), 2);
        env.step(2);
        assert_eq!(env.cut_value(), 4);
        assert!(env.done());
        assert_eq!(MaxCutEnv::compute_cut(&env.graph, env.solution_mask()), 4);
    }

    #[test]
    fn prop_incremental_cut_matches_oracle() {
        prop::check_msg(
            "maxcut-incremental",
            25,
            |r| {
                let n = 6 + r.gen_range(30);
                (generators::erdos_renyi(n, 0.3, r), r.next_u64())
            },
            |(g, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                let mut env = MaxCutEnv::new(g.clone());
                for _ in 0..g.n {
                    if env.done() {
                        break;
                    }
                    let cands: Vec<usize> = (0..g.n)
                        .filter(|&v| env.is_candidate(v) && env.gain(v) > 0)
                        .collect();
                    if cands.is_empty() {
                        break;
                    }
                    env.step(cands[rng.gen_range(cands.len())]);
                    let oracle = MaxCutEnv::compute_cut(g, env.solution_mask());
                    if oracle != env.cut_value() {
                        return Err(format!(
                            "cut mismatch: inc {} vs oracle {oracle}",
                            env.cut_value()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
