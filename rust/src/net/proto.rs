//! The serve wire protocol (DESIGN.md §10).
//!
//! Requests are newline-delimited, one per line, in either of two forms —
//! both parse to the same [`JobSpec`] the file-mode front door uses:
//!
//! * the batch-solve manifest grammar (`gen er n=20 seed=7 mvc id=a`),
//!   so a jobs file can be piped to the socket unchanged;
//! * a JSON object: `{"id":"a","gen":"er","n":20,"seed":7,`
//!   `"scenario":"mvc","max_latency_ms":250}` or
//!   `{"id":"r","file":"graphs/road.txt"}`. Unknown keys are rejected
//!   (same typo-hardening as the manifest grammar). `{"op":"stats"}`
//!   requests an admission-counters line instead of a solve;
//!   `{"op":"drain"}` asks the server to drain gracefully (DESIGN.md §11).
//!
//! Responses are one JSON object per line: [`JobEvent`] outcome lines
//! (`crate::service::JobEvent::to_json`), error lines
//! ([`error_json`]), backpressure reject lines ([`reject_json`] /
//! [`busy_json`], marked `"rejected":true` so clients can retry), and
//! stats lines ([`stats_json`]).

use crate::batch::{parse_job_line, GraphSource, JobSpec};
use crate::env::Scenario;
use crate::runtime::ExecStats;
use crate::service::AdmissionSnapshot;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// One parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Solve this job.
    Job(JobSpec),
    /// Report admission/backpressure counters (`{"op":"stats"}`).
    Stats,
    /// Gracefully drain the server (`{"op":"drain"}`): stop accepting,
    /// flush open packs, finish in-flight work, stream every remaining
    /// outcome, exit 0 (DESIGN.md §11). Equivalent to SIGTERM.
    Drain,
}

/// Keys accepted in a JSON job request (everything else is a hard error:
/// a typo'd `"sed":7` must not silently run with a default seed).
const JOB_KEYS: &[&str] =
    &["id", "scenario", "file", "gen", "n", "rho", "d", "triad", "seed", "max_latency_ms"];

/// Parse one request line. `Ok(None)` for blank/comment lines;
/// `index` numbers per-connection defaults (`id=job<index>`, generator
/// seed) exactly like the file-mode manifest parser, counting this
/// connection's job requests only.
pub fn parse_request(line: &str, index: usize) -> Result<Option<Request>> {
    let t = line.trim();
    if !t.starts_with('{') {
        // Blank/comment handling and the full grammar live in the manifest
        // parser — one grammar, two transports.
        return Ok(parse_job_line(t, index)?.map(Request::Job));
    }
    let j = Json::parse(t).context("request is not valid JSON")?;
    if let Some(op) = j.get("op") {
        let op = op.as_str().context("'op' must be a string")?;
        if op == "stats" {
            return Ok(Some(Request::Stats));
        }
        if op == "drain" {
            return Ok(Some(Request::Drain));
        }
        bail!("unknown op '{op}' (known: stats, drain)");
    }
    for k in j.keys() {
        if !JOB_KEYS.contains(&k) {
            bail!("unknown request key '{k}' (allowed: {})", JOB_KEYS.join(", "));
        }
    }
    let str_key = |key: &str| -> Result<Option<&str>> {
        match j.get(key) {
            Some(v) => Ok(Some(
                v.as_str().with_context(|| format!("'{key}' must be a string"))?,
            )),
            None => Ok(None),
        }
    };
    let int_key = |key: &str| -> Result<Option<u64>> {
        match j.get(key) {
            Some(v) => Ok(Some(v.as_u64().with_context(|| {
                format!("'{key}' must be a non-negative integer")
            })?)),
            None => Ok(None),
        }
    };
    let num_key = |key: &str| -> Result<Option<f64>> {
        match j.get(key) {
            Some(v) => {
                Ok(Some(v.as_f64().with_context(|| format!("'{key}' must be a number"))?))
            }
            None => Ok(None),
        }
    };
    let id = str_key("id")?.map(|s| s.to_string()).unwrap_or_else(|| format!("job{index}"));
    let scenario = match str_key("scenario")? {
        Some(s) => Scenario::parse(s)?,
        None => Scenario::Mvc,
    };
    let max_latency_ms = int_key("max_latency_ms")?;
    let source = match str_key("file")? {
        Some(path) => {
            for k in ["gen", "n", "rho", "d", "triad", "seed"] {
                if j.get(k).is_some() {
                    bail!("'file' requests take no '{k}' (generator keys are for 'gen')");
                }
            }
            GraphSource::File(PathBuf::from(path))
        }
        None => {
            let model = str_key("gen")?.unwrap_or("er").to_string();
            if !matches!(model.as_str(), "er" | "ba" | "hk") {
                bail!("unknown generator '{model}' (er|ba|hk)");
            }
            GraphSource::Gen {
                model,
                n: int_key("n")?.unwrap_or(250) as usize,
                rho: num_key("rho")?.unwrap_or(0.15),
                d: int_key("d")?.unwrap_or(4) as usize,
                triad: num_key("triad")?.unwrap_or(0.25),
                seed: int_key("seed")?.unwrap_or(index as u64),
            }
        }
    };
    Ok(Some(Request::Job(JobSpec { id, scenario, source, max_latency_ms })))
}

/// A per-job error line (parse/materialize/solve failures — terminal for
/// the job, not retryable).
pub fn error_json(id: &str, error: &str) -> Json {
    Json::obj().set("id", id).set("error", error)
}

/// A quota-backpressure reject line: the tenant is at its load quota.
/// `"rejected":true` marks it retryable; queue depth and the tenant's
/// current load give the client its retry context.
pub fn reject_json(id: &str, reason: &str, depth: usize, load: usize) -> Json {
    Json::obj()
        .set("id", id)
        .set("error", reason)
        .set("rejected", true)
        .set("queue_depth", depth)
        .set("tenant_load", load)
}

/// A queue-backpressure reject line: the bounded admission queue is full
/// (written by the connection reader itself, before admission).
pub fn busy_json(id: &str, queue_cap: usize) -> Json {
    Json::obj()
        .set("id", id)
        .set("error", "server busy: admission queue full")
        .set("rejected", true)
        .set("queue_cap", queue_cap)
}

/// The `{"op":"stats"}` response: current admission counters plus the
/// runtime/transport counters accumulated over finished packs (h2d/d2h
/// bytes, restarts, and the per-rank transport `tx_bytes`/`rx_bytes` —
/// DESIGN.md §12).
pub fn stats_json(snap: &AdmissionSnapshot, exec: &ExecStats) -> Json {
    Json::obj()
        .set("op", "stats")
        .set("stats", crate::coordinator::metrics::admission_stats_json(snap))
        .set("exec", crate::coordinator::metrics::exec_stats_json(exec))
}

/// The `{"op":"drain"}` acknowledgment: drain accepted, with the work
/// still owed (all of it will be streamed before the server exits).
pub fn drain_json(pending: usize, in_flight: usize) -> Json {
    Json::obj()
        .set("op", "drain")
        .set("draining", true)
        .set("pending", pending)
        .set("in_flight", in_flight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_and_grammar_forms_parse_to_the_same_spec() {
        let a = parse_request("gen er n=20 seed=7 maxcut id=alpha", 0).unwrap().unwrap();
        let b = parse_request(
            r#"{"id":"alpha","gen":"er","n":20,"seed":7,"scenario":"maxcut"}"#,
            0,
        )
        .unwrap()
        .unwrap();
        assert_eq!(a, b);
        match a {
            Request::Job(spec) => {
                assert_eq!(spec.id, "alpha");
                assert_eq!(spec.scenario, Scenario::MaxCut);
                assert_eq!(spec.max_latency_ms, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn json_defaults_match_the_grammar_defaults() {
        let a = parse_request("gen er", 3).unwrap().unwrap();
        let b = parse_request("{}", 3).unwrap().unwrap();
        assert_eq!(a, b, "empty JSON object = default generator job");
        let Request::Job(spec) = b else { panic!() };
        assert_eq!(spec.id, "job3");
        assert_eq!(
            spec.source,
            GraphSource::Gen { model: "er".into(), n: 250, rho: 0.15, d: 4, triad: 0.25, seed: 3 }
        );
    }

    #[test]
    fn deadline_file_and_stats_requests() {
        let r = parse_request(r#"{"id":"d","n":24,"max_latency_ms":250}"#, 0).unwrap().unwrap();
        let Request::Job(spec) = r else { panic!() };
        assert_eq!(spec.max_latency_ms, Some(250));

        let r = parse_request(r#"{"id":"f","file":"graphs/road.txt","scenario":"mis"}"#, 0)
            .unwrap()
            .unwrap();
        let Request::Job(spec) = r else { panic!() };
        assert_eq!(spec.source, GraphSource::File(PathBuf::from("graphs/road.txt")));

        assert_eq!(parse_request(r#"{"op":"stats"}"#, 0).unwrap(), Some(Request::Stats));
        assert_eq!(parse_request(r#"{"op":"drain"}"#, 0).unwrap(), Some(Request::Drain));
        assert!(parse_request("", 0).unwrap().is_none());
        assert!(parse_request("# comment", 0).unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_requests() {
        // Typos, bad types, unknown ops, broken JSON: all hard errors.
        assert!(parse_request(r#"{"sed":7}"#, 0).is_err());
        assert!(parse_request(r#"{"op":"solve-everything"}"#, 0).is_err());
        assert!(parse_request(r#"{"n":"twenty"}"#, 0).is_err());
        assert!(parse_request(r#"{"max_latency_ms":-1}"#, 0).is_err());
        assert!(parse_request(r#"{"file":"a.txt","n":20}"#, 0).is_err());
        assert!(parse_request(r#"{"gen":"zz"}"#, 0).is_err());
        assert!(parse_request(r#"{"id":"a""#, 0).is_err());
        assert!(parse_request("gen zz n=10", 0).is_err());
    }

    #[test]
    fn response_shapes() {
        let s = reject_json("j1", "tenant 3 at load quota", 5, 8).render();
        assert!(s.contains("\"rejected\":true"), "{s}");
        assert!(s.contains("\"queue_depth\":5"), "{s}");
        assert!(s.contains("\"tenant_load\":8"), "{s}");
        let s = busy_json("j2", 256).render();
        assert!(s.contains("\"rejected\":true") && s.contains("\"queue_cap\":256"), "{s}");
        let mut exec = ExecStats::default();
        exec.tx_bytes = 96;
        let s = stats_json(&AdmissionSnapshot::default(), &exec).render();
        assert!(s.contains("\"op\":\"stats\"") && s.contains("\"in_flight\":0"), "{s}");
        assert!(s.contains("\"exec\":{") && s.contains("\"tx_bytes\":96"), "{s}");
        let s = error_json("j3", "boom").render();
        assert!(s.contains("\"error\":\"boom\"") && !s.contains("rejected"), "{s}");
        let s = drain_json(3, 2).render();
        assert!(s.contains("\"op\":\"drain\""), "{s}");
        assert!(s.contains("\"draining\":true"), "{s}");
        assert!(s.contains("\"pending\":3") && s.contains("\"in_flight\":2"), "{s}");
    }
}
