//! The tick driver: one clock for every serve front end.
//!
//! Max-wait and per-job deadlines are *clock* launches — they must fire
//! even when no request line arrives to piggyback on. Both serve modes
//! therefore block on a channel with a timeout bounded by
//! [`Admitter::next_due`](crate::service::Admitter::next_due):
//!
//! * file/stdin mode reads lines on a side thread
//!   ([`spawn_line_reader`]) so the main loop can wake for a due pack
//!   while the stream is idle;
//! * the TCP front loop receives every message (jobs, EOFs, finished
//!   packs) through one channel and uses the same [`recv_deadline`].
//!
//! Timeouts mean "a pack came due" — the caller runs `tick()` and goes
//! back to waiting. No polling interval, no busy loop: the sleep is
//! exactly as long as the earliest deadline.

use std::io::BufRead;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::time::Instant;

/// Read lines on a dedicated thread, forwarding each over a bounded
/// channel (capacity 256: backpressure instead of buffering a whole job
/// file). The channel closes at EOF or on the first read error (the error
/// is forwarded first).
pub fn spawn_line_reader(
    reader: Box<dyn BufRead + Send>,
) -> Receiver<std::io::Result<String>> {
    let (tx, rx) = mpsc::sync_channel(256);
    std::thread::Builder::new()
        .name("oggm-lines".into())
        .spawn(move || {
            for line in reader.lines() {
                let stop = line.is_err();
                if tx.send(line).is_err() || stop {
                    break;
                }
            }
        })
        .expect("spawning the line-reader thread");
    rx
}

/// Receive the next message, waking at `due` if nothing arrives first.
/// `Err(Timeout)` means the deadline passed — tick the admission clock and
/// call again. With no deadline pending this blocks indefinitely
/// (`Err(Disconnected)` when every sender is gone).
pub fn recv_deadline<T>(
    rx: &Receiver<T>,
    due: Option<Instant>,
) -> Result<T, RecvTimeoutError> {
    match due {
        None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
        Some(at) => rx.recv_timeout(at.saturating_duration_since(Instant::now())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn line_reader_streams_then_closes() {
        let rx = spawn_line_reader(Box::new(std::io::Cursor::new("a\nb\n")));
        assert_eq!(rx.recv().unwrap().unwrap(), "a");
        assert_eq!(rx.recv().unwrap().unwrap(), "b");
        assert!(rx.recv().is_err(), "channel must close at EOF");
    }

    #[test]
    fn recv_deadline_times_out_and_blocks() {
        let (tx, rx) = mpsc::channel::<u32>();
        // A due instant in the past times out immediately.
        let r = recv_deadline(&rx, Some(Instant::now()));
        assert_eq!(r, Err(RecvTimeoutError::Timeout));
        // A pending message beats any deadline.
        tx.send(7).unwrap();
        let r = recv_deadline(&rx, Some(Instant::now() + Duration::from_secs(60)));
        assert_eq!(r, Ok(7));
        // No deadline + closed channel = Disconnected, not a hang.
        drop(tx);
        assert_eq!(recv_deadline(&rx, None), Err(RecvTimeoutError::Disconnected));
    }
}
