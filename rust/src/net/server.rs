//! The TCP serve front: accept/reader/writer/front/solver thread assembly
//! (see the module docs in `net/mod.rs` and DESIGN.md §10/§11).

use crate::batch::queue::{Job, PackStat};
use crate::batch::spec::JobSpec;
use crate::batch::BatchCfg;
use crate::graph::Graph;
use crate::model::Params;
use crate::net::{driver, proto};
use crate::runtime::{ExecStats, Manifest, Runtime};
use crate::service::{
    AdmitError, Admitter, AdmissionSnapshot, Executor, JobEvent, Options, PackDone, PackRun,
    SubmitMeta,
};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default per-tenant load quota when `--quota` is not given: deep enough
/// to fill several packs per tenant, small enough that one firehose
/// connection cannot monopolize the session.
pub const DEFAULT_QUOTA: usize = 64;

/// Outbound lines buffered per connection before the server declares the
/// client a slow consumer and disconnects it (DESIGN.md §11): the front
/// thread must never block on one tenant's unread socket.
pub const WRITER_BUF: usize = 1024;

/// What a finished server run did. A server returns after `--max-conns`
/// connections drain, or after a graceful drain (`{"op":"drain"}` /
/// SIGTERM); without either it runs until killed.
#[derive(Debug)]
pub struct NetSummary {
    /// Connections served to completion (including force-disconnects).
    pub conns: u64,
    /// Job requests received (after parse, before admission).
    pub jobs: u64,
    /// JSONL lines enqueued to clients (outcome + error + stats lines).
    pub lines_out: u64,
    /// Error/reject lines among them.
    pub failed: u64,
    /// Connections force-closed because their outbound buffer overflowed
    /// (slow consumers, DESIGN.md §11).
    pub slow_disconnects: u64,
    /// Whether the run ended via graceful drain (`{"op":"drain"}` or
    /// SIGTERM) rather than `--max-conns` exhaustion.
    pub drained: bool,
    /// Per-pack statistics, in launch order (successful packs).
    pub packs: Vec<PackStat>,
    /// Final admission counters.
    pub snapshot: AdmissionSnapshot,
}

/// Everything the front loop can receive: connection lifecycle, parsed
/// jobs, control requests, and finished packs — one channel, so
/// [`driver::recv_deadline`] is the loop's only wait point.
enum FrontMsg {
    /// The accept thread registered a connection (its writer channel, the
    /// shutdown handle, and the writer thread's join handle).
    Conn { tenant: u64, out: SyncSender<String>, sock: Arc<TcpStream>, join: JoinHandle<()> },
    /// A parsed + materialized job request.
    Job { tenant: u64, spec: JobSpec, graph: Graph },
    /// A request line that failed to parse/materialize (per-job error).
    BadLine { tenant: u64, id: String, error: String },
    /// `{"op":"stats"}`.
    Stats { tenant: u64 },
    /// `{"op":"drain"}` or SIGTERM (tenant 0 = no acknowledging socket).
    Drain { tenant: u64 },
    /// The tenant's input reached EOF (half-close or disconnect).
    Eof { tenant: u64 },
    /// The solver finished a pack.
    Done(PackDone),
    /// The accept loop stopped after spawning `conns` readers.
    AcceptDone { conns: u64 },
}

/// What solves launched packs on the solver thread.
enum Solver {
    /// Production: construct a [`Runtime`] *inside* the solver thread (a
    /// runtime is single-threaded) and run an [`Executor`] session on it.
    Real {
        /// Artifact directory to load the runtime from.
        dir: PathBuf,
        /// Batch configuration (engine, storage, policy, retry budgets).
        cfg: BatchCfg,
        /// Model parameters to serve.
        params: Params,
        /// `--fault-plan` spec for the executor's rank pool (None falls
        /// back to `OGGM_FAULT_PLAN`).
        fault_spec: Option<String>,
        /// `--ranks` transport spec: TCP listen addresses for
        /// process-separated rank workers (None = in-process threads,
        /// DESIGN.md §12).
        ranks: Option<String>,
        /// `--token` shared secret for the rank Hello handshake (None
        /// falls back to `OGGM_TOKEN`).
        token: Option<String>,
    },
    /// Tests/benches: an injected solve function (deterministic timing, no
    /// artifacts needed).
    Custom(Box<dyn FnMut(PackRun) -> PackDone + Send>),
}

/// Serve the listener with the real solver: artifacts at `dir`, `params`
/// as the session's θ. Blocks until the server drains (see
/// [`NetSummary`]); without [`Options::max_conns`] or a drain request that
/// is "forever".
pub fn serve(
    listener: TcpListener,
    dir: impl Into<PathBuf>,
    params: Params,
    opts: &Options,
) -> Result<NetSummary> {
    let dir = dir.into();
    let manifest = Manifest::load(&dir)?;
    let solver = Solver::Real {
        dir,
        cfg: BatchCfg::from(opts),
        params,
        fault_spec: opts.fault_plan.clone(),
        ranks: opts.ranks.clone(),
        token: opts.token.clone(),
    };
    run_server(listener, manifest, opts, solver)
}

/// Serve the listener with an injected pack solver — the deterministic
/// hook `rust/tests/net.rs` and `bench_service_load` use (admission,
/// batching, deadlines, and quotas are all exercised for real; only the
/// device solve is substituted). `manifest` supplies the compiled shapes
/// admission packs against.
pub fn serve_with(
    listener: TcpListener,
    manifest: Manifest,
    opts: &Options,
    solve: Box<dyn FnMut(PackRun) -> PackDone + Send>,
) -> Result<NetSummary> {
    run_server(listener, manifest, opts, Solver::Custom(solve))
}

/// Per-connection state the front thread tracks. Outbound lines go through
/// `out` to the connection's writer thread ([`writer_loop`]); `sock` is
/// the shutdown handle the supervisor uses to unblock reads / cut off a
/// slow consumer.
struct Conn {
    out: SyncSender<String>,
    sock: Arc<TcpStream>,
    join: Option<JoinHandle<()>>,
    eof: bool,
}

/// The front thread's view of every live connection, plus the outbound
/// accounting. Owns the slow-consumer policy: a tenant whose writer buffer
/// is full when a line arrives is disconnected on the spot.
struct Conns {
    map: HashMap<u64, Conn>,
    /// Writer join handles of closed connections, joined at shutdown so
    /// every enqueued line is flushed before the server returns.
    writers: Vec<JoinHandle<()>>,
    lines_out: u64,
    slow_disconnects: u64,
    closed: u64,
}

impl Conns {
    fn new() -> Conns {
        Conns {
            map: HashMap::new(),
            writers: Vec::new(),
            lines_out: 0,
            slow_disconnects: 0,
            closed: 0,
        }
    }

    /// Enqueue one JSONL line to a tenant's writer. Silently drops lines
    /// for vanished connections (a client that disconnected early still
    /// had its pack solved — co-packed tenants needed it). A full buffer
    /// disconnects the slow consumer (DESIGN.md §11).
    fn write(&mut self, tenant: u64, json: &Json) {
        let Some(conn) = self.map.get(&tenant) else { return };
        let mut line = json.render();
        line.push('\n');
        match conn.out.try_send(line) {
            Ok(()) => self.lines_out += 1,
            Err(TrySendError::Full(_)) => {
                // Slow consumer: its unread backlog hit WRITER_BUF lines.
                // Cut it off — the front thread must not block or buffer
                // unboundedly for one tenant.
                self.slow_disconnects += 1;
                self.drop_conn(tenant, Shutdown::Both);
            }
            Err(TrySendError::Disconnected(_)) => {} // writer died with its socket
        }
    }

    /// Mark a tenant's input as ended (no-op for unknown tenants).
    fn eof(&mut self, tenant: u64) {
        if let Some(c) = self.map.get_mut(&tenant) {
            c.eof = true;
        }
    }

    /// Close out a tenant whose input ended and whose last outcome is
    /// enqueued: dropping the writer sender lets the writer thread flush
    /// the backlog, half-close our write side (the client's read loop sees
    /// EOF), and exit.
    fn maybe_close(&mut self, adm: &Admitter, tenant: u64) {
        let done = self
            .map
            .get(&tenant)
            .map(|c| c.eof && adm.tenant_load(tenant) == 0)
            .unwrap_or(false);
        if done {
            self.drop_conn(tenant, Shutdown::Read);
        }
    }

    /// Remove a connection: count it closed, unblock its reader via `how`,
    /// and stash the writer handle for the shutdown join. The writer keeps
    /// flushing until every sender (front + reader) is gone.
    fn drop_conn(&mut self, tenant: u64, how: Shutdown) {
        if let Some(mut c) = self.map.remove(&tenant) {
            self.closed += 1;
            let _ = c.sock.shutdown(how);
            if let Some(j) = c.join.take() {
                self.writers.push(j);
            }
        }
    }

    /// Drain-exit teardown: close every remaining connection (their
    /// readers unblock via `Shutdown::Read`; their writers flush whatever
    /// is enqueued, FIN, and exit), then join every writer so no outcome
    /// line is lost to process exit.
    fn shutdown_all(&mut self) {
        let tenants: Vec<u64> = self.map.keys().copied().collect();
        for t in tenants {
            self.drop_conn(t, Shutdown::Read);
        }
        for j in self.writers.drain(..) {
            let _ = j.join();
        }
    }
}

fn run_server(
    listener: TcpListener,
    manifest: Manifest,
    opts: &Options,
    solver: Solver,
) -> Result<NetSummary> {
    let queue_cap = opts.queue_cap.max(1);
    let addr = listener.local_addr().ok();
    // The ONE front channel: bounded, so total parsed-but-unadmitted jobs
    // are capped; readers try_send jobs and reject on Full.
    let (tx, rx) = mpsc::sync_channel::<FrontMsg>(queue_cap);
    let (run_tx, run_rx) = mpsc::channel::<PackRun>();
    let solver_handle = spawn_solver(solver, run_rx, tx.clone());
    // Reader-side queue-full rejects never reach this thread (that is the
    // point); they are counted here and folded into the Admitter's books.
    let queue_full = Arc::new(AtomicU64::new(0));
    let stop_accept = Arc::new(AtomicBool::new(false));
    let accept_tx = tx.clone();
    let accept_stop = stop_accept.clone();
    let accept_qf = queue_full.clone();
    let max_conns = opts.max_conns;
    std::thread::Builder::new()
        .name("oggm-accept".into())
        .spawn(move || accept_loop(listener, accept_tx, queue_cap, max_conns, accept_stop, accept_qf))
        .context("spawning the accept thread")?;
    // SIGTERM becomes a drain request on this channel (self-pipe trick).
    sigterm::route_to(tx.clone());
    // The front loop owns no sender; every remaining clone lives in a
    // worker thread (or the SIGTERM router, cleared below), so
    // Disconnected can only mean total shutdown.
    drop(tx);

    let mut adm = Admitter::new(manifest, opts.p)
        .launch_policy(opts.launch)
        .max_wait(opts.max_wait)
        .quota(Some(opts.quota.unwrap_or(DEFAULT_QUOTA)));
    let mut conns = Conns::new();
    let mut packs: Vec<PackStat> = Vec::new();
    let (mut total_conns, mut jobs_in) = (None::<u64>, 0u64);
    let mut failed = 0u64;
    let mut draining = false;
    // Runtime/transport counters summed over finished packs, surfaced by
    // the `{"op":"stats"}` probe next to the admission snapshot.
    let mut exec_total = ExecStats::default();

    loop {
        // Fold reader-side queue-full rejects into the admission books so
        // stats probes and the final snapshot see them.
        for _ in 0..queue_full.swap(0, Ordering::Relaxed) {
            adm.record_queue_full();
        }
        match driver::recv_deadline(&rx, adm.next_due()) {
            Err(RecvTimeoutError::Timeout) => {
                // A pack came due (deadline or max-wait) with no traffic.
                send_runs(&run_tx, adm.tick(Instant::now()));
            }
            Err(RecvTimeoutError::Disconnected) => break,
            Ok(FrontMsg::Conn { tenant, out, sock, join }) => {
                conns.map.insert(tenant, Conn { out, sock, join: Some(join), eof: false });
            }
            Ok(FrontMsg::Job { tenant, spec, graph }) => {
                jobs_in += 1;
                let id = spec.id.clone();
                if draining {
                    // Drain protocol: jobs already admitted finish; jobs
                    // arriving after the drain request are refused with a
                    // terminal error line (DESIGN.md §11).
                    failed += 1;
                    conns.write(
                        tenant,
                        &proto::error_json(&id, "server is draining: job not admitted"),
                    );
                    continue;
                }
                let meta = SubmitMeta {
                    tenant,
                    max_latency: spec.max_latency_ms.map(Duration::from_millis),
                };
                let job = Job {
                    id: spec.id,
                    scenario: opts.scenario.unwrap_or(spec.scenario),
                    graph,
                };
                match adm.submit(job, meta) {
                    Ok((_, runs)) => send_runs(&run_tx, runs),
                    Err(AdmitError::Busy { reason, depth, load }) => {
                        failed += 1;
                        conns.write(tenant, &proto::reject_json(&id, &reason, depth, load));
                    }
                    Err(AdmitError::Invalid(e)) => {
                        failed += 1;
                        conns.write(tenant, &proto::error_json(&id, &format!("{e:#}")));
                    }
                }
            }
            Ok(FrontMsg::BadLine { tenant, id, error }) => {
                failed += 1;
                conns.write(tenant, &proto::error_json(&id, &error));
            }
            Ok(FrontMsg::Stats { tenant }) => {
                conns.write(tenant, &proto::stats_json(&adm.snapshot(), &exec_total));
            }
            Ok(FrontMsg::Drain { tenant }) => {
                let snap = adm.snapshot();
                conns.write(tenant, &proto::drain_json(snap.pending, snap.in_flight));
                if !draining {
                    draining = true;
                    // Stop accepting, then nudge the blocked accept loop
                    // with a throwaway self-connection so it observes the
                    // flag and reports AcceptDone.
                    stop_accept.store(true, Ordering::Release);
                    nudge_accept(addr);
                    // Flush every open pack: admitted jobs all solve.
                    send_runs(&run_tx, adm.flush());
                }
            }
            Ok(FrontMsg::Eof { tenant }) => {
                conns.eof(tenant);
                // This tenant sends nothing more: its jobs must not wait
                // for other tenants' traffic to fill a pack.
                send_runs(&run_tx, adm.flush_tenant(tenant));
                conns.maybe_close(&adm, tenant);
            }
            Ok(FrontMsg::Done(done)) => {
                adm.record_retries(done.retries as u64, done.faults as u64);
                let mut touched = Vec::with_capacity(done.events.len());
                for ev in done.events {
                    adm.complete(ev.tenant, 1);
                    if ev.result.is_err() {
                        failed += 1;
                    }
                    conns.write(ev.tenant, &ev.to_json());
                    touched.push(ev.tenant);
                }
                if let Some(stat) = done.stat {
                    exec_total.add(&stat.exec);
                    let snap = adm.snapshot();
                    eprintln!(
                        "serve: pack {:>3}: {:>6} N={:<5} jobs={:<3} cause={:<8} sim {:.4}s \
                         | depth={} open={} in_flight={}{}",
                        stat.pack, stat.scenario.name(), stat.bucket_n, stat.jobs,
                        stat.cause.name(), stat.sim_time,
                        snap.pending, snap.open_packs, snap.in_flight,
                        if stat.retries > 0 {
                            format!(" retries={}", stat.retries)
                        } else {
                            String::new()
                        }
                    );
                    packs.push(stat);
                }
                touched.sort_unstable();
                touched.dedup();
                for tenant in touched {
                    conns.maybe_close(&adm, tenant);
                }
            }
            Ok(FrontMsg::AcceptDone { conns: n }) => {
                total_conns = Some(n);
            }
        }
        let idle = adm.pending() == 0 && adm.snapshot().in_flight == 0;
        // Graceful-drain exit: accepting stopped, every admitted job's
        // outcome is enqueued — regardless of clients still holding their
        // sockets open (shutdown_all flushes and closes them).
        if draining && total_conns.is_some() && idle {
            break;
        }
        // Drained exit (--max-conns): the listener stopped, every
        // connection closed out, and nothing is queued or in flight.
        if total_conns == Some(conns.closed) && idle {
            break;
        }
    }
    sigterm::unroute();
    // Drop the front receiver FIRST: any reader still blocked on a full
    // channel fails its send, exits, and releases its writer sender —
    // otherwise the writer joins below could deadlock.
    drop(rx);
    // Flush and close every remaining connection; join the writers so no
    // enqueued outcome line is lost to process exit.
    conns.shutdown_all();
    // Closing the run channel stops the solver; its FrontMsg sender drops
    // with it.
    drop(run_tx);
    let _ = solver_handle.join();
    for _ in 0..queue_full.swap(0, Ordering::Relaxed) {
        adm.record_queue_full();
    }
    Ok(NetSummary {
        conns: conns.closed,
        jobs: jobs_in,
        lines_out: conns.lines_out,
        failed,
        slow_disconnects: conns.slow_disconnects,
        drained: draining,
        packs,
        snapshot: adm.snapshot(),
    })
}

/// Forward launched packs to the solver thread (a send failure means the
/// solver is gone — the front loop will exit via Disconnected).
fn send_runs(run_tx: &mpsc::Sender<PackRun>, runs: Vec<PackRun>) {
    for run in runs {
        let _ = run_tx.send(run);
    }
}

/// Unblock the accept loop after `stop` was raised: a throwaway loopback
/// connection makes `listener.incoming()` yield so the flag is observed.
fn nudge_accept(addr: Option<SocketAddr>) {
    if let Some(a) = addr {
        let _ = TcpStream::connect_timeout(&a, Duration::from_millis(250));
    }
}

/// Accept connections until the listener errors fatally, `max_conns` is
/// reached, or a drain raises `stop`; one reader + one writer thread per
/// connection. Tenant ids start at 1 (0 is the library/file-mode default
/// tenant).
fn accept_loop(
    listener: TcpListener,
    tx: SyncSender<FrontMsg>,
    queue_cap: usize,
    max_conns: Option<usize>,
    stop: Arc<AtomicBool>,
    queue_full: Arc<AtomicU64>,
) {
    let mut spawned = 0u64;
    for stream in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            // Drain: the nudge (or a late client) connected only to get
            // us here; close it unserved.
            break;
        }
        let Ok(stream) = stream else { continue };
        let (Ok(wstream), Ok(sock)) = (stream.try_clone(), stream.try_clone()) else {
            continue;
        };
        let tenant = spawned + 1;
        // Bounded per-connection outbound buffer: the front thread
        // try_sends lines; the writer owns the socket's write side.
        let (out, out_rx) = mpsc::sync_channel::<String>(WRITER_BUF);
        let Ok(join) = std::thread::Builder::new()
            .name(format!("oggm-write-{tenant}"))
            .spawn(move || writer_loop(wstream, out_rx))
        else {
            continue;
        };
        // Registration goes through the same channel BEFORE the reader is
        // spawned, so the front thread always knows the tenant's writer by
        // the time its first job needs an outcome line routed.
        if tx.send(FrontMsg::Conn { tenant, out: out.clone(), sock: Arc::new(sock), join }).is_err()
        {
            return;
        }
        let tx2 = tx.clone();
        let qf = queue_full.clone();
        let ok = std::thread::Builder::new()
            .name(format!("oggm-conn-{tenant}"))
            .spawn(move || reader_loop(tenant, stream, out, tx2, queue_cap, qf))
            .is_ok();
        if !ok {
            // Registered but reader-less: a synthetic EOF closes it out
            // (zero load, so the front thread drops it immediately).
            let _ = tx.send(FrontMsg::Eof { tenant });
        }
        spawned += 1;
        if let Some(cap) = max_conns {
            if spawned >= cap as u64 {
                break;
            }
        }
    }
    let _ = tx.send(FrontMsg::AcceptDone { conns: spawned });
}

/// Per-connection writer: the single owner of the socket's write side.
/// Drains the bounded line channel in FIFO order; after a write error it
/// keeps draining (so senders never block on a dead socket) and finally
/// half-closes the write side — the client's read loop sees EOF exactly
/// when the last enqueued line is out.
fn writer_loop(mut stream: TcpStream, rx: Receiver<String>) {
    let mut ok = true;
    for line in rx {
        if ok && stream.write_all(line.as_bytes()).is_err() {
            ok = false;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

/// Per-connection reader: parse request lines, materialize graphs, and
/// forward jobs with `try_send` — a full front channel becomes an
/// immediate backpressure reject on this socket (counted in
/// `queue_full`), written through the connection's writer channel so the
/// overloaded front thread never sees the job at all.
fn reader_loop(
    tenant: u64,
    stream: TcpStream,
    out: SyncSender<String>,
    tx: SyncSender<FrontMsg>,
    queue_cap: usize,
    queue_full: Arc<AtomicU64>,
) {
    let (mut jobs, mut lineno) = (0usize, 0usize);
    for line in BufReader::new(stream).lines() {
        lineno += 1;
        // A read error (reset, aborted) ends the connection like EOF.
        let Ok(raw) = line else { break };
        match proto::parse_request(&raw, jobs) {
            Ok(None) => continue,
            Ok(Some(proto::Request::Stats)) => {
                if tx.send(FrontMsg::Stats { tenant }).is_err() {
                    return;
                }
            }
            Ok(Some(proto::Request::Drain)) => {
                if tx.send(FrontMsg::Drain { tenant }).is_err() {
                    return;
                }
            }
            Ok(Some(proto::Request::Job(spec))) => {
                jobs += 1;
                let id = spec.id.clone();
                match spec.materialize() {
                    Ok(graph) => match tx.try_send(FrontMsg::Job { tenant, spec, graph }) {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) => {
                            queue_full.fetch_add(1, Ordering::Relaxed);
                            let mut line = proto::busy_json(&id, queue_cap).render();
                            line.push('\n');
                            // Best effort: if even the writer buffer is
                            // full, the slow-consumer policy is about to
                            // disconnect this tenant anyway.
                            let _ = out.try_send(line);
                        }
                        Err(TrySendError::Disconnected(_)) => return,
                    },
                    Err(e) => {
                        let msg = FrontMsg::BadLine { tenant, id, error: format!("{e:#}") };
                        if tx.send(msg).is_err() {
                            return;
                        }
                    }
                }
            }
            Err(e) => {
                let msg = FrontMsg::BadLine {
                    tenant,
                    id: format!("line{lineno}"),
                    error: format!("{e:#}"),
                };
                if tx.send(msg).is_err() {
                    return;
                }
            }
        }
    }
    let _ = tx.send(FrontMsg::Eof { tenant });
}

/// The solver thread: pull launched packs, solve, push results. The real
/// variant constructs its [`Runtime`] here — in-thread — because runtimes
/// are single-threaded by design; a startup failure degrades to contextful
/// per-job error events rather than killing the server.
fn spawn_solver(
    solver: Solver,
    run_rx: Receiver<PackRun>,
    tx: SyncSender<FrontMsg>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("oggm-solver".into())
        .spawn(move || match solver {
            Solver::Custom(mut solve) => {
                for run in run_rx {
                    if tx.send(FrontMsg::Done(solve(run))).is_err() {
                        break;
                    }
                }
            }
            Solver::Real { dir, cfg, params, fault_spec, ranks, token } => {
                match Runtime::new(&dir) {
                Ok(rt) => {
                    let mut exec = Executor::new(&rt, params, cfg)
                        .fault_plan(fault_spec)
                        .rank_transport(ranks)
                        .rank_token(token);
                    for run in run_rx {
                        if tx.send(FrontMsg::Done(exec.run(run))).is_err() {
                            break;
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("runtime startup failed: {e:#}");
                    for run in run_rx {
                        if tx.send(FrontMsg::Done(fail_pack(run, &msg))).is_err() {
                            break;
                        }
                    }
                }
            }},
        })
        .expect("spawning the solver thread")
}

/// Turn a pack into per-job error events (solver could not start).
fn fail_pack(run: PackRun, msg: &str) -> PackDone {
    let started = Instant::now();
    let PackRun { pack, scenario, bucket, members, .. } = run;
    let err = format!("pack {pack} ({scenario}, N={bucket}): {msg}");
    let events = members
        .into_iter()
        .map(|m| JobEvent {
            job: m.job,
            id: m.id,
            scenario,
            tenant: m.tenant,
            wait_ms: started.saturating_duration_since(m.submitted).as_secs_f64() * 1e3,
            result: Err(err.clone()),
        })
        .collect();
    PackDone { events, stat: None, retries: 0, faults: 0 }
}

/// SIGTERM → graceful drain, via the classic self-pipe trick: the handler
/// (async-signal-safe: one `write(2)`) pokes a pipe; a watcher thread
/// turns each poke into a [`FrontMsg::Drain`] for the most recently
/// started server. Declared raw because the repo links no libc crate.
#[cfg(unix)]
mod sigterm {
    use super::FrontMsg;
    use std::sync::atomic::{AtomicI32, Ordering};
    use std::sync::mpsc::SyncSender;
    use std::sync::{Mutex, Once};

    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn pipe(fds: *mut i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }

    /// Write end of the self-pipe (-1 until installed).
    static PIPE_W: AtomicI32 = AtomicI32::new(-1);
    static INSTALL: Once = Once::new();
    /// The server currently receiving SIGTERM drains (last started wins;
    /// cleared when its run ends).
    static TARGET: Mutex<Option<SyncSender<FrontMsg>>> = Mutex::new(None);

    /// Async-signal-safe SIGTERM handler: one byte into the pipe.
    extern "C" fn on_sigterm(_sig: i32) {
        let fd = PIPE_W.load(Ordering::Relaxed);
        if fd >= 0 {
            let b = [1u8];
            unsafe {
                let _ = write(fd, b.as_ptr(), 1);
            }
        }
    }

    /// Route SIGTERM to `tx` as a drain request; install the handler and
    /// watcher thread once per process.
    pub(super) fn route_to(tx: SyncSender<FrontMsg>) {
        *TARGET.lock().unwrap() = Some(tx);
        INSTALL.call_once(|| {
            let mut fds = [-1i32; 2];
            if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
                return;
            }
            let rd = fds[0];
            let spawned = std::thread::Builder::new()
                .name("oggm-sigterm".into())
                .spawn(move || loop {
                    let mut b = [0u8; 1];
                    if unsafe { read(rd, b.as_mut_ptr(), 1) } <= 0 {
                        return;
                    }
                    // tenant 0 never has a socket: the ack is dropped,
                    // the drain proceeds.
                    let target = TARGET.lock().unwrap().clone();
                    if let Some(tx) = target {
                        let _ = tx.send(FrontMsg::Drain { tenant: 0 });
                    }
                })
                .is_ok();
            if spawned {
                PIPE_W.store(fds[1], Ordering::Relaxed);
                unsafe {
                    signal(SIGTERM, on_sigterm as usize);
                }
            }
        });
    }

    /// Stop routing SIGTERM to a finished server (and drop its channel
    /// sender, so the front channel can fully disconnect).
    pub(super) fn unroute() {
        *TARGET.lock().unwrap() = None;
    }
}

/// Non-unix stub: no signal plumbing; `{"op":"drain"}` still works.
#[cfg(not(unix))]
mod sigterm {
    use super::FrontMsg;
    use std::sync::mpsc::SyncSender;

    pub(super) fn route_to(_tx: SyncSender<FrontMsg>) {}
    pub(super) fn unroute() {}
}
