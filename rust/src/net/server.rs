//! The TCP serve front: accept/reader/front/solver thread assembly (see
//! the module docs in `net/mod.rs` and DESIGN.md §10).

use crate::batch::queue::{Job, PackStat};
use crate::batch::spec::JobSpec;
use crate::batch::BatchCfg;
use crate::graph::Graph;
use crate::model::Params;
use crate::net::{driver, proto};
use crate::runtime::{Manifest, Runtime};
use crate::service::{
    AdmitError, Admitter, AdmissionSnapshot, Executor, JobEvent, Options, PackDone, PackRun,
    SubmitMeta,
};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Default per-tenant load quota when `--quota` is not given: deep enough
/// to fill several packs per tenant, small enough that one firehose
/// connection cannot monopolize the session.
pub const DEFAULT_QUOTA: usize = 64;

/// What a finished server run did (only reachable with
/// [`Options::max_conns`] — an unbounded server runs until killed).
#[derive(Debug)]
pub struct NetSummary {
    /// Connections served.
    pub conns: u64,
    /// Job requests received (after parse, before admission).
    pub jobs: u64,
    /// JSONL lines written to clients.
    pub lines_out: u64,
    /// Error/reject lines among them.
    pub failed: u64,
    /// Per-pack statistics, in launch order (successful packs).
    pub packs: Vec<PackStat>,
    /// Final admission counters.
    pub snapshot: AdmissionSnapshot,
}

/// Everything the front loop can receive: connection lifecycle, parsed
/// jobs, control requests, and finished packs — one channel, so
/// [`driver::recv_deadline`] is the loop's only wait point.
enum FrontMsg {
    /// A reader thread registered its connection.
    Conn { tenant: u64, writer: Arc<Mutex<TcpStream>> },
    /// A parsed + materialized job request.
    Job { tenant: u64, spec: JobSpec, graph: Graph },
    /// A request line that failed to parse/materialize (per-job error).
    BadLine { tenant: u64, id: String, error: String },
    /// `{"op":"stats"}`.
    Stats { tenant: u64 },
    /// The tenant's input reached EOF (half-close or disconnect).
    Eof { tenant: u64 },
    /// The solver finished a pack.
    Done(PackDone),
    /// The accept loop stopped after spawning `conns` readers.
    AcceptDone { conns: u64 },
}

/// What solves launched packs on the solver thread.
enum Solver {
    /// Production: construct a [`Runtime`] *inside* the solver thread (a
    /// runtime is single-threaded) and run an [`Executor`] session on it.
    Real {
        /// Artifact directory to load the runtime from.
        dir: PathBuf,
        /// Batch configuration (engine, storage, policy).
        cfg: BatchCfg,
        /// Model parameters to serve.
        params: Params,
    },
    /// Tests/benches: an injected solve function (deterministic timing, no
    /// artifacts needed).
    Custom(Box<dyn FnMut(PackRun) -> PackDone + Send>),
}

/// Serve the listener with the real solver: artifacts at `dir`, `params`
/// as the session's θ. Blocks until the server drains (see
/// [`NetSummary`]); without [`Options::max_conns`] that is "forever".
pub fn serve(
    listener: TcpListener,
    dir: impl Into<PathBuf>,
    params: Params,
    opts: &Options,
) -> Result<NetSummary> {
    let dir = dir.into();
    let manifest = Manifest::load(&dir)?;
    let solver = Solver::Real { dir, cfg: BatchCfg::from(opts), params };
    run_server(listener, manifest, opts, solver)
}

/// Serve the listener with an injected pack solver — the deterministic
/// hook `rust/tests/net.rs` and `bench_service_load` use (admission,
/// batching, deadlines, and quotas are all exercised for real; only the
/// device solve is substituted). `manifest` supplies the compiled shapes
/// admission packs against.
pub fn serve_with(
    listener: TcpListener,
    manifest: Manifest,
    opts: &Options,
    solve: Box<dyn FnMut(PackRun) -> PackDone + Send>,
) -> Result<NetSummary> {
    run_server(listener, manifest, opts, Solver::Custom(solve))
}

/// Per-connection state the front thread tracks.
struct Conn {
    writer: Arc<Mutex<TcpStream>>,
    eof: bool,
}

fn run_server(
    listener: TcpListener,
    manifest: Manifest,
    opts: &Options,
    solver: Solver,
) -> Result<NetSummary> {
    let queue_cap = opts.queue_cap.max(1);
    // The ONE front channel: bounded, so total parsed-but-unadmitted jobs
    // are capped; readers try_send jobs and reject on Full.
    let (tx, rx) = mpsc::sync_channel::<FrontMsg>(queue_cap);
    let (run_tx, run_rx) = mpsc::channel::<PackRun>();
    let solver_handle = spawn_solver(solver, run_rx, tx.clone());
    let accept_tx = tx.clone();
    let max_conns = opts.max_conns;
    std::thread::Builder::new()
        .name("oggm-accept".into())
        .spawn(move || accept_loop(listener, accept_tx, queue_cap, max_conns))
        .context("spawning the accept thread")?;
    // The front loop owns no sender; every remaining clone lives in a
    // worker thread, so Disconnected can only mean total shutdown.
    drop(tx);

    let mut adm = Admitter::new(manifest, opts.p)
        .launch_policy(opts.launch)
        .max_wait(opts.max_wait)
        .quota(Some(opts.quota.unwrap_or(DEFAULT_QUOTA)));
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut packs: Vec<PackStat> = Vec::new();
    let (mut total_conns, mut closed, mut jobs_in) = (None::<u64>, 0u64, 0u64);
    let (mut lines_out, mut failed) = (0u64, 0u64);

    loop {
        match driver::recv_deadline(&rx, adm.next_due()) {
            Err(RecvTimeoutError::Timeout) => {
                // A pack came due (deadline or max-wait) with no traffic.
                send_runs(&run_tx, adm.tick(Instant::now()));
            }
            Err(RecvTimeoutError::Disconnected) => break,
            Ok(FrontMsg::Conn { tenant, writer }) => {
                conns.insert(tenant, Conn { writer, eof: false });
            }
            Ok(FrontMsg::Job { tenant, spec, graph }) => {
                jobs_in += 1;
                let id = spec.id.clone();
                let meta = SubmitMeta {
                    tenant,
                    max_latency: spec.max_latency_ms.map(Duration::from_millis),
                };
                let job = Job {
                    id: spec.id,
                    scenario: opts.scenario.unwrap_or(spec.scenario),
                    graph,
                };
                match adm.submit(job, meta) {
                    Ok((_, runs)) => send_runs(&run_tx, runs),
                    Err(AdmitError::Busy { reason, depth, load }) => {
                        failed += 1;
                        write_to(&conns, tenant, &proto::reject_json(&id, &reason, depth, load),
                                 &mut lines_out);
                    }
                    Err(AdmitError::Invalid(e)) => {
                        failed += 1;
                        write_to(&conns, tenant, &proto::error_json(&id, &format!("{e:#}")),
                                 &mut lines_out);
                    }
                }
            }
            Ok(FrontMsg::BadLine { tenant, id, error }) => {
                failed += 1;
                write_to(&conns, tenant, &proto::error_json(&id, &error), &mut lines_out);
            }
            Ok(FrontMsg::Stats { tenant }) => {
                write_to(&conns, tenant, &proto::stats_json(&adm.snapshot()), &mut lines_out);
            }
            Ok(FrontMsg::Eof { tenant }) => {
                if let Some(c) = conns.get_mut(&tenant) {
                    c.eof = true;
                }
                // This tenant sends nothing more: its jobs must not wait
                // for other tenants' traffic to fill a pack.
                send_runs(&run_tx, adm.flush_tenant(tenant));
                closed += maybe_close(&adm, &mut conns, tenant);
            }
            Ok(FrontMsg::Done(done)) => {
                let mut touched = Vec::with_capacity(done.events.len());
                for ev in done.events {
                    adm.complete(ev.tenant, 1);
                    if ev.result.is_err() {
                        failed += 1;
                    }
                    write_to(&conns, ev.tenant, &ev.to_json(), &mut lines_out);
                    touched.push(ev.tenant);
                }
                if let Some(stat) = done.stat {
                    let snap = adm.snapshot();
                    eprintln!(
                        "serve: pack {:>3}: {:>6} N={:<5} jobs={:<3} cause={:<8} sim {:.4}s \
                         | depth={} open={} in_flight={}",
                        stat.pack, stat.scenario.name(), stat.bucket_n, stat.jobs,
                        stat.cause.name(), stat.sim_time,
                        snap.pending, snap.open_packs, snap.in_flight
                    );
                    packs.push(stat);
                }
                touched.sort_unstable();
                touched.dedup();
                for tenant in touched {
                    closed += maybe_close(&adm, &mut conns, tenant);
                }
            }
            Ok(FrontMsg::AcceptDone { conns: n }) => {
                total_conns = Some(n);
            }
        }
        // Drained exit: the listener stopped, every connection closed out,
        // and nothing is queued or in flight.
        if total_conns == Some(closed)
            && adm.pending() == 0
            && adm.snapshot().in_flight == 0
        {
            break;
        }
    }
    // Closing the run channel stops the solver; its FrontMsg sender drops
    // with it.
    drop(run_tx);
    let _ = solver_handle.join();
    Ok(NetSummary {
        conns: closed,
        jobs: jobs_in,
        lines_out,
        failed,
        packs,
        snapshot: adm.snapshot(),
    })
}

/// Forward launched packs to the solver thread (a send failure means the
/// solver is gone — the front loop will exit via Disconnected).
fn send_runs(run_tx: &mpsc::Sender<PackRun>, runs: Vec<PackRun>) {
    for run in runs {
        let _ = run_tx.send(run);
    }
}

/// Write one JSONL line to a tenant's socket, counting it. Silently drops
/// lines for vanished connections (a client that disconnected early still
/// had its pack solved — co-packed tenants needed it).
fn write_to(conns: &HashMap<u64, Conn>, tenant: u64, json: &Json, lines_out: &mut u64) {
    let Some(conn) = conns.get(&tenant) else { return };
    let mut line = json.render();
    line.push('\n');
    if let Ok(mut w) = conn.writer.lock() {
        if (*w).write_all(line.as_bytes()).is_ok() {
            *lines_out += 1;
        }
    }
}

/// Close out a tenant whose input ended and whose last outcome is written:
/// half-close our write side (the client's read loop sees EOF) and drop
/// the registration. Returns 1 when the connection closed.
fn maybe_close(adm: &Admitter, conns: &mut HashMap<u64, Conn>, tenant: u64) -> u64 {
    let done = conns
        .get(&tenant)
        .map(|c| c.eof && adm.tenant_load(tenant) == 0)
        .unwrap_or(false);
    if !done {
        return 0;
    }
    if let Some(c) = conns.remove(&tenant) {
        if let Ok(w) = c.writer.lock() {
            let _ = w.shutdown(Shutdown::Write);
        }
    }
    1
}

/// Accept connections until the listener errors fatally or `max_conns` is
/// reached; one reader thread per connection. Tenant ids start at 1 (0 is
/// the library/file-mode default tenant).
fn accept_loop(
    listener: TcpListener,
    tx: SyncSender<FrontMsg>,
    queue_cap: usize,
    max_conns: Option<usize>,
) {
    let mut spawned = 0u64;
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let Ok(writer) = stream.try_clone() else { continue };
        let tenant = spawned + 1;
        let writer = Arc::new(Mutex::new(writer));
        let tx2 = tx.clone();
        let ok = std::thread::Builder::new()
            .name(format!("oggm-conn-{tenant}"))
            .spawn(move || reader_loop(tenant, stream, writer, tx2, queue_cap))
            .is_ok();
        if ok {
            spawned += 1;
        }
        if let Some(cap) = max_conns {
            if spawned >= cap as u64 {
                break;
            }
        }
    }
    let _ = tx.send(FrontMsg::AcceptDone { conns: spawned });
}

/// Per-connection reader: parse request lines, materialize graphs, and
/// forward jobs with `try_send` — a full front channel becomes an
/// immediate backpressure reject on this socket, written right here so the
/// overloaded front thread never sees the job at all.
fn reader_loop(
    tenant: u64,
    stream: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    tx: SyncSender<FrontMsg>,
    queue_cap: usize,
) {
    if tx.send(FrontMsg::Conn { tenant, writer: writer.clone() }).is_err() {
        return;
    }
    let (mut jobs, mut lineno) = (0usize, 0usize);
    for line in BufReader::new(stream).lines() {
        lineno += 1;
        // A read error (reset, aborted) ends the connection like EOF.
        let Ok(raw) = line else { break };
        match proto::parse_request(&raw, jobs) {
            Ok(None) => continue,
            Ok(Some(proto::Request::Stats)) => {
                if tx.send(FrontMsg::Stats { tenant }).is_err() {
                    return;
                }
            }
            Ok(Some(proto::Request::Job(spec))) => {
                jobs += 1;
                let id = spec.id.clone();
                match spec.materialize() {
                    Ok(graph) => match tx.try_send(FrontMsg::Job { tenant, spec, graph }) {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) => {
                            let mut line = proto::busy_json(&id, queue_cap).render();
                            line.push('\n');
                            if let Ok(mut w) = writer.lock() {
                                let _ = (*w).write_all(line.as_bytes());
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => return,
                    },
                    Err(e) => {
                        let msg = FrontMsg::BadLine { tenant, id, error: format!("{e:#}") };
                        if tx.send(msg).is_err() {
                            return;
                        }
                    }
                }
            }
            Err(e) => {
                let msg = FrontMsg::BadLine {
                    tenant,
                    id: format!("line{lineno}"),
                    error: format!("{e:#}"),
                };
                if tx.send(msg).is_err() {
                    return;
                }
            }
        }
    }
    let _ = tx.send(FrontMsg::Eof { tenant });
}

/// The solver thread: pull launched packs, solve, push results. The real
/// variant constructs its [`Runtime`] here — in-thread — because runtimes
/// are single-threaded by design; a startup failure degrades to contextful
/// per-job error events rather than killing the server.
fn spawn_solver(
    solver: Solver,
    run_rx: Receiver<PackRun>,
    tx: SyncSender<FrontMsg>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("oggm-solver".into())
        .spawn(move || match solver {
            Solver::Custom(mut solve) => {
                for run in run_rx {
                    if tx.send(FrontMsg::Done(solve(run))).is_err() {
                        break;
                    }
                }
            }
            Solver::Real { dir, cfg, params } => match Runtime::new(&dir) {
                Ok(rt) => {
                    let mut exec = Executor::new(&rt, params, cfg);
                    for run in run_rx {
                        if tx.send(FrontMsg::Done(exec.run(run))).is_err() {
                            break;
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("runtime startup failed: {e:#}");
                    for run in run_rx {
                        if tx.send(FrontMsg::Done(fail_pack(run, &msg))).is_err() {
                            break;
                        }
                    }
                }
            },
        })
        .expect("spawning the solver thread")
}

/// Turn a pack into per-job error events (solver could not start).
fn fail_pack(run: PackRun, msg: &str) -> PackDone {
    let started = Instant::now();
    let PackRun { pack, scenario, bucket, members, .. } = run;
    let err = format!("pack {pack} ({scenario}, N={bucket}): {msg}");
    let events = members
        .into_iter()
        .map(|m| JobEvent {
            job: m.job,
            id: m.id,
            scenario,
            tenant: m.tenant,
            wait_ms: started.saturating_duration_since(m.submitted).as_secs_f64() * 1e3,
            result: Err(err.clone()),
        })
        .collect();
    PackDone { events, stat: None }
}
