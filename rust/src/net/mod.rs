//! The networked serve front door (DESIGN.md §10): a TCP listener
//! speaking newline-delimited JSONL over the service's admission +
//! execution halves.
//!
//! One process, four thread roles:
//!
//! * **accept** — owns the [`std::net::TcpListener`]; spawns one reader
//!   thread per connection (each connection is one *tenant*).
//! * **reader** (per connection) — parses request lines
//!   ([`proto::parse_request`]: the batch-solve manifest grammar or its
//!   JSON object form), materializes graphs, and forwards jobs into the
//!   *bounded* front channel. A full channel rejects the job right here
//!   with a backpressure line — admission memory is capped no matter how
//!   fast clients write.
//! * **front** — the only thread that touches the
//!   [`Admitter`](crate::service::Admitter): multiplexes every
//!   connection's jobs into one warm session's open packs, applies
//!   per-tenant quotas, launches packs (fill / deadline / max-wait /
//!   tenant EOF) onto the solver channel, and routes finished
//!   [`JobEvent`](crate::service::JobEvent)s back to each tenant's socket.
//!   Its clock is [`driver::recv_deadline`] bounded by
//!   [`Admitter::next_due`](crate::service::Admitter::next_due), so
//!   deadline launches fire with zero client traffic.
//! * **solver** — owns its own [`Runtime`](crate::runtime::Runtime)
//!   (single-threaded by design) inside an
//!   [`Executor`](crate::service::Executor), pulling launched
//!   [`PackRun`](crate::service::PackRun)s and pushing results back as
//!   they finish. **Continuous batching** falls out of the split: while a
//!   pack solves here, the front thread keeps admitting new arrivals into
//!   the next open packs (`rust/tests/net.rs` pins it).
//!
//! Shutdown: a client half-closing its write side (EOF) flushes that
//! tenant's open packs, and the server half-closes back once its last
//! outcome is written. With `--max-conns N` the listener stops after N
//! connections and [`server::serve`] returns a [`server::NetSummary`]
//! once they drain — the deterministic mode CI smokes and
//! `bench_service_load` use. Without it the process serves until killed.

/// Tick/clock plumbing shared by the net front loop and file-mode serve.
pub mod driver;
/// Wire protocol: request-line parsing and response JSON shapes.
pub mod proto;
/// The TCP listener: accept/reader/front/solver thread assembly.
pub mod server;

pub use proto::Request;
pub use server::{serve, serve_with, NetSummary};
