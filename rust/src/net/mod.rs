//! The networked serve front door (DESIGN.md §10): a TCP listener
//! speaking newline-delimited JSONL over the service's admission +
//! execution halves.
//!
//! One process, five thread roles:
//!
//! * **accept** — owns the [`std::net::TcpListener`]; spawns one reader
//!   and one writer thread per connection (each connection is one
//!   *tenant*).
//! * **reader** (per connection) — parses request lines
//!   ([`proto::parse_request`]: the batch-solve manifest grammar or its
//!   JSON object form), materializes graphs, and forwards jobs into the
//!   *bounded* front channel. A full channel rejects the job right here
//!   with a backpressure line — admission memory is capped no matter how
//!   fast clients write (and the reject is counted:
//!   `queue_full_rejects` in the stats probe).
//! * **writer** (per connection) — the single owner of the socket's write
//!   side, fed by a bounded line channel ([`server::WRITER_BUF`]). The
//!   front thread `try_send`s outcome lines; a tenant whose buffer is
//!   full when a line arrives is a *slow consumer* and is disconnected on
//!   the spot (counted in [`server::NetSummary::slow_disconnects`]) — no
//!   client can block or bloat the server by not reading.
//! * **front** — the only thread that touches the
//!   [`Admitter`](crate::service::Admitter): multiplexes every
//!   connection's jobs into one warm session's open packs, applies
//!   per-tenant quotas, launches packs (fill / deadline / max-wait /
//!   tenant EOF) onto the solver channel, and routes finished
//!   [`JobEvent`](crate::service::JobEvent)s back to each tenant's socket.
//!   Its clock is [`driver::recv_deadline`] bounded by
//!   [`Admitter::next_due`](crate::service::Admitter::next_due), so
//!   deadline launches fire with zero client traffic.
//! * **solver** — owns its own [`Runtime`](crate::runtime::Runtime)
//!   (single-threaded by design) inside an
//!   [`Executor`](crate::service::Executor), pulling launched
//!   [`PackRun`](crate::service::PackRun)s and pushing results back as
//!   they finish. **Continuous batching** falls out of the split: while a
//!   pack solves here, the front thread keeps admitting new arrivals into
//!   the next open packs (`rust/tests/net.rs` pins it).
//!
//! Shutdown: a client half-closing its write side (EOF) flushes that
//! tenant's open packs, and the server half-closes back once its last
//! outcome is written. With `--max-conns N` the listener stops after N
//! connections and [`server::serve`] returns a [`server::NetSummary`]
//! once they drain — the deterministic mode CI smokes and
//! `bench_service_load` use. A `{"op":"drain"}` request — or SIGTERM,
//! routed through a self-pipe — drains *gracefully* (DESIGN.md §11):
//! accepting stops, open packs flush, in-flight work finishes, every
//! admitted job streams exactly one outcome line, and the server returns
//! with `drained: true` (jobs arriving after the drain get a terminal
//! error line instead of silence). Without any of these the process
//! serves until killed.

/// Tick/clock plumbing shared by the net front loop and file-mode serve.
pub mod driver;
/// Wire protocol: request-line parsing and response JSON shapes.
pub mod proto;
/// The TCP listener: accept/reader/writer/front/solver thread assembly.
pub mod server;

pub use proto::Request;
pub use server::{serve, serve_with, NetSummary};
