//! Artifact manifest parsing (artifacts/manifest.tsv).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One manifest row: a compiled stage at a concrete shape.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub stage: String,
    pub b: usize,
    pub n: usize,
    pub ni: usize,
    pub k: usize,
    pub num_outputs: usize,
    pub file: PathBuf,
}

/// The parsed artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub k: usize,
    pub l: usize,
    pub entries: HashMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let mut entries = HashMap::new();
        let (mut k, mut l) = (32usize, 2usize);
        for (lineno, line) in text.lines().enumerate() {
            if line.starts_with('#') {
                // Header carries `k=..` / `l=..` metadata fields.
                for tok in line.trim_start_matches('#').split_whitespace() {
                    for part in tok.split('\t') {
                        if let Some(v) = part.strip_prefix("k=") {
                            k = v.parse().context("bad k in manifest header")?;
                        } else if let Some(v) = part.strip_prefix("l=") {
                            l = v.parse().context("bad l in manifest header")?;
                        }
                    }
                }
                continue;
            }
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 8 {
                bail!("manifest line {} has {} columns", lineno + 1, cols.len());
            }
            let info = ArtifactInfo {
                name: cols[0].to_string(),
                stage: cols[1].to_string(),
                b: cols[2].parse()?,
                n: cols[3].parse()?,
                ni: cols[4].parse()?,
                k: cols[5].parse()?,
                num_outputs: cols[6].parse()?,
                file: dir.join(cols[7]),
            };
            entries.insert(info.name.clone(), info);
        }
        if entries.is_empty() {
            bail!("manifest {} contains no entries", path.display());
        }
        Ok(Manifest { dir, k, l, entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.entries.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest ({} entries); \
                 add its shape to python/compile/configs.py and re-run `make artifacts`",
                self.entries.len()
            )
        })
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Smallest compiled q_scores bucket N that fits n nodes on p shards,
    /// among entries additionally satisfying `pred`. Shared core of
    /// `bucket_for` / `bucket_for_any_batch` so bucket-selection rules
    /// cannot drift between the single-graph and batched paths.
    fn smallest_bucket(
        &self,
        n: usize,
        p: usize,
        pred: impl Fn(&ArtifactInfo) -> bool,
    ) -> Option<usize> {
        self.entries
            .values()
            .filter(|e| {
                e.stage == "q_scores" && e.n >= n && e.n % p == 0 && e.ni == e.n / p && pred(e)
            })
            .map(|e| e.n)
            .min()
    }

    /// Smallest compiled bucket N that fits a graph of `n` nodes with `p`
    /// shards at batch size `b` (inference stages).
    pub fn bucket_for(&self, n: usize, p: usize, b: usize) -> Result<usize> {
        self.smallest_bucket(n, p, |e| e.b == b).with_context(|| {
            format!(
                "no compiled bucket fits n={n}, P={p}, B={b}; \
                 add one to python/compile/configs.py and re-run `make artifacts`"
            )
        })
    }

    /// Batch sizes with compiled fwd stages at bucket `n`, shard height
    /// `ni`, ascending. These are the pack capacities the batched solve
    /// engine can step through (eviction/compaction drops to the smallest
    /// capacity that still fits the active graphs).
    pub fn batch_sizes(&self, n: usize, ni: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.stage == "q_scores" && e.n == n && e.ni == ni)
            .map(|e| e.b)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Smallest compiled bucket N that fits a graph of `n` nodes with `p`
    /// shards at *any* batch size (the batched engine picks capacities per
    /// step from `batch_sizes`).
    pub fn bucket_for_any_batch(&self, n: usize, p: usize) -> Result<usize> {
        self.smallest_bucket(n, p, |_| true).with_context(|| {
            format!(
                "no compiled bucket fits n={n}, P={p} at any batch size; \
                 add one to python/compile/configs.py and re-run `make artifacts`"
            )
        })
    }

    /// All (n, ni) fwd shard configs available for batch size b.
    pub fn available_fwd_shapes(&self, b: usize) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .entries
            .values()
            .filter(|e| e.stage == "q_scores" && e.b == b)
            .map(|e| (e.n, e.ni))
            .collect();
        v.sort_unstable();
        v
    }
}

/// Default artifacts directory: `$OGGM_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("OGGM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_format() {
        let dir = std::env::temp_dir().join(format!("oggm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# oggm artifact manifest\tk=32\tl=2\n\
             # name\tstage\tb\tn\tni\tk\tnum_outputs\tfile\n\
             q_scores_b1_n24_ni12_k32\tq_scores\t1\t24\t12\t32\t1\tq.hlo.txt\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.k, 32);
        assert_eq!(m.l, 2);
        let e = m.get("q_scores_b1_n24_ni12_k32").unwrap();
        assert_eq!(e.ni, 12);
        assert_eq!(e.num_outputs, 1);
        assert!(m.get("nope").is_err());
        assert_eq!(m.available_fwd_shapes(1), vec![(24, 12)]);
        assert_eq!(m.batch_sizes(24, 12), vec![1]);
        assert!(m.batch_sizes(24, 24).is_empty());
        assert_eq!(m.bucket_for_any_batch(20, 2).unwrap(), 24);
        assert!(m.bucket_for_any_batch(20, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_if_built() {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert_eq!(m.k, 32);
        assert!(m.entries.len() > 200, "expected full artifact set");
        // Spot-check a few names the coordinator depends on.
        for name in [
            "embed_pre_b1_n24_ni24_k32",
            "embed_msg_b1_n1488_ni248_k32",
            "q_scores_bwd_b8_n24_ni12_k32",
        ] {
            assert!(m.has(name), "{name} missing");
        }
    }
}
