//! Artifact manifest parsing (artifacts/manifest.tsv).
//!
//! Column semantics: (name, stage, b, n, ni, k, num_outputs, file). Sparse
//! stages overload the shape slots exactly as python/compile/configs.py
//! does — for `embed_msg_sp`/`embed_msg_sp_bwd`, n = EC (edge capacity)
//! and ni = NC (node chunk); for `embed_pre_sp`/`embed_pre_sp_bwd`, n = 0
//! (the stage is N-free). The sparse lookup helpers below decode that.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One manifest row: a compiled stage at a concrete shape.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Artifact name (`<stage>_b<B>_n<N>_ni<NI>_k<K>`).
    pub name: String,
    /// Stage family (e.g. `embed_msg`, `embed_msg_sp`).
    pub stage: String,
    /// Batch size B.
    pub b: usize,
    /// Padded node count N (sparse overloads: EC for msg_sp, 0 for pre_sp).
    pub n: usize,
    /// Shard height NI (sparse overload: node chunk NC for msg_sp).
    pub ni: usize,
    /// Embedding dimension K.
    pub k: usize,
    /// Number of tuple outputs the artifact returns.
    pub num_outputs: usize,
    /// HLO-text file backing this artifact.
    pub file: PathBuf,
}

/// The parsed artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Embedding dimension K of the artifact set.
    pub k: usize,
    /// Embedding layers L recorded by the build step.
    pub l: usize,
    /// All artifacts by name.
    pub entries: HashMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let mut entries = HashMap::new();
        let (mut k, mut l) = (32usize, 2usize);
        for (lineno, line) in text.lines().enumerate() {
            if line.starts_with('#') {
                // Header carries `k=..` / `l=..` metadata fields.
                for tok in line.trim_start_matches('#').split_whitespace() {
                    for part in tok.split('\t') {
                        if let Some(v) = part.strip_prefix("k=") {
                            k = v.parse().context("bad k in manifest header")?;
                        } else if let Some(v) = part.strip_prefix("l=") {
                            l = v.parse().context("bad l in manifest header")?;
                        }
                    }
                }
                continue;
            }
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 8 {
                bail!("manifest line {} has {} columns", lineno + 1, cols.len());
            }
            let info = ArtifactInfo {
                name: cols[0].to_string(),
                stage: cols[1].to_string(),
                b: cols[2].parse()?,
                n: cols[3].parse()?,
                ni: cols[4].parse()?,
                k: cols[5].parse()?,
                num_outputs: cols[6].parse()?,
                file: dir.join(cols[7]),
            };
            entries.insert(info.name.clone(), info);
        }
        if entries.is_empty() {
            bail!("manifest {} contains no entries", path.display());
        }
        Ok(Manifest { dir, k, l, entries })
    }

    /// Look up an artifact, with build guidance on a miss.
    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.entries.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest ({} entries); \
                 add its shape to python/compile/configs.py and re-run `make artifacts`",
                self.entries.len()
            )
        })
    }

    /// Whether an artifact name is present.
    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Smallest compiled q_scores bucket N that fits n nodes on p shards,
    /// among entries additionally satisfying `pred`. Shared core of
    /// `bucket_for` / `bucket_for_any_batch` so bucket-selection rules
    /// cannot drift between the single-graph and batched paths.
    fn smallest_bucket(
        &self,
        n: usize,
        p: usize,
        pred: impl Fn(&ArtifactInfo) -> bool,
    ) -> Option<usize> {
        self.entries
            .values()
            .filter(|e| {
                e.stage == "q_scores" && e.n >= n && e.n % p == 0 && e.ni == e.n / p && pred(e)
            })
            .map(|e| e.n)
            .min()
    }

    /// Smallest compiled bucket N that fits a graph of `n` nodes with `p`
    /// shards at batch size `b` (inference stages).
    pub fn bucket_for(&self, n: usize, p: usize, b: usize) -> Result<usize> {
        self.smallest_bucket(n, p, |e| e.b == b).with_context(|| {
            format!(
                "no compiled bucket fits n={n}, P={p}, B={b}; \
                 add one to python/compile/configs.py and re-run `make artifacts`"
            )
        })
    }

    /// Batch sizes with compiled fwd stages at bucket `n`, shard height
    /// `ni`, ascending. These are the pack capacities the batched solve
    /// engine can step through (eviction/compaction drops to the smallest
    /// capacity that still fits the active graphs).
    pub fn batch_sizes(&self, n: usize, ni: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.stage == "q_scores" && e.n == n && e.ni == ni)
            .map(|e| e.b)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Smallest compiled bucket N that fits a graph of `n` nodes with `p`
    /// shards at *any* batch size (the batched engine picks capacities per
    /// step from `batch_sizes`).
    pub fn bucket_for_any_batch(&self, n: usize, p: usize) -> Result<usize> {
        self.smallest_bucket(n, p, |_| true).with_context(|| {
            format!(
                "no compiled bucket fits n={n}, P={p} at any batch size; \
                 add one to python/compile/configs.py and re-run `make artifacts`"
            )
        })
    }

    /// Node chunk NC the sparse path should use at batch size `b`, shard
    /// height `ni`: the largest compiled `embed_msg_sp` chunk that is <= ni,
    /// else the smallest available (chunks need not divide NI — the
    /// coordinator zero-pads the last source chunk and clips the last
    /// destination chunk). Mirrors python/compile/configs.py `chunk_for`.
    pub fn sparse_chunk_for(&self, b: usize, ni: usize, k: usize) -> Option<usize> {
        let mut chunks: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.stage == "embed_msg_sp" && e.b == b && e.k == k)
            .map(|e| e.ni)
            .collect();
        chunks.sort_unstable();
        chunks.dedup();
        chunks.iter().rev().find(|&&nc| nc <= ni).or(chunks.first()).copied()
    }

    /// Ascending edge-capacity ladder compiled for (`stage`, b, chunk):
    /// the EC values `SparseShard` may pad its tiles to.
    pub fn edge_caps(&self, stage: &str, b: usize, chunk: usize, k: usize) -> Vec<usize> {
        let mut caps: Vec<usize> = self
            .entries
            .values()
            .filter(|e| e.stage == stage && e.b == b && e.ni == chunk && e.k == k)
            .map(|e| e.n)
            .collect();
        caps.sort_unstable();
        caps.dedup();
        caps
    }

    /// Resolve the sparse compute configuration for (b, ni): the node
    /// chunk and forward edge-capacity ladder, erroring with build guidance
    /// when the sparse stages are not compiled for this shape.
    pub fn sparse_config(&self, b: usize, ni: usize, k: usize) -> Result<(usize, Vec<usize>)> {
        let pre = crate::runtime::sparse_pre_name("embed_pre_sp", b, ni, k);
        if !self.has(&pre) {
            bail!(
                "sparse path needs artifact '{pre}'; add the bucket to \
                 python/compile/configs.py sparse_fwd_shapes() and re-run `make artifacts`"
            );
        }
        let chunk = self.sparse_chunk_for(b, ni, k).with_context(|| {
            format!(
                "no embed_msg_sp chunks compiled at B={b}, K={k}; \
                 add them to python/compile/configs.py and re-run `make artifacts`"
            )
        })?;
        let caps = self.edge_caps("embed_msg_sp", b, chunk, k);
        if caps.is_empty() {
            bail!("no embed_msg_sp edge capacities at B={b}, NC={chunk}, K={k}");
        }
        Ok((chunk, caps))
    }

    /// All (n, ni) fwd shard configs available for batch size b.
    pub fn available_fwd_shapes(&self, b: usize) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .entries
            .values()
            .filter(|e| e.stage == "q_scores" && e.b == b)
            .map(|e| (e.n, e.ni))
            .collect();
        v.sort_unstable();
        v
    }
}

/// Default artifacts directory: `$OGGM_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("OGGM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_format() {
        let dir = std::env::temp_dir().join(format!("oggm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# oggm artifact manifest\tk=32\tl=2\n\
             # name\tstage\tb\tn\tni\tk\tnum_outputs\tfile\n\
             q_scores_b1_n24_ni12_k32\tq_scores\t1\t24\t12\t32\t1\tq.hlo.txt\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.k, 32);
        assert_eq!(m.l, 2);
        let e = m.get("q_scores_b1_n24_ni12_k32").unwrap();
        assert_eq!(e.ni, 12);
        assert_eq!(e.num_outputs, 1);
        assert!(m.get("nope").is_err());
        assert_eq!(m.available_fwd_shapes(1), vec![(24, 12)]);
        assert_eq!(m.batch_sizes(24, 12), vec![1]);
        assert!(m.batch_sizes(24, 24).is_empty());
        assert_eq!(m.bucket_for_any_batch(20, 2).unwrap(), 24);
        assert!(m.bucket_for_any_batch(20, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_lookup_decodes_overloaded_columns() {
        let dir = std::env::temp_dir().join(format!("oggm_manifest_sp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# oggm artifact manifest\tk=32\tl=2\n\
             embed_pre_sp_b1_n0_ni24_k32\tembed_pre_sp\t1\t0\t24\t32\t1\tp.hlo.txt\n\
             embed_msg_sp_b1_n96_ni12_k32\tembed_msg_sp\t1\t96\t12\t32\t1\tm1.hlo.txt\n\
             embed_msg_sp_b1_n768_ni12_k32\tembed_msg_sp\t1\t768\t12\t32\t1\tm2.hlo.txt\n\
             embed_msg_sp_b1_n768_ni48_k32\tembed_msg_sp\t1\t768\t48\t32\t1\tm3.hlo.txt\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        // Largest chunk <= NI wins; smaller NI falls back to the smallest.
        assert_eq!(m.sparse_chunk_for(1, 24, 32), Some(12));
        assert_eq!(m.sparse_chunk_for(1, 48, 32), Some(48));
        assert_eq!(m.sparse_chunk_for(1, 8, 32), Some(12));
        assert_eq!(m.sparse_chunk_for(2, 24, 32), None); // no B=2 entries
        assert_eq!(m.edge_caps("embed_msg_sp", 1, 12, 32), vec![96, 768]);
        assert_eq!(m.edge_caps("embed_msg_sp", 1, 48, 32), vec![768]);
        assert!(m.edge_caps("embed_msg_sp_bwd", 1, 12, 32).is_empty());
        let (chunk, caps) = m.sparse_config(1, 24, 32).unwrap();
        assert_eq!((chunk, caps), (12, vec![96, 768]));
        // Missing the N-free pre stage is an actionable error.
        assert!(m.sparse_config(2, 24, 32).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_names_match_python() {
        assert_eq!(
            crate::runtime::sparse_pre_name("embed_pre_sp", 1, 24, 32),
            "embed_pre_sp_b1_n0_ni24_k32"
        );
        assert_eq!(
            crate::runtime::sparse_msg_name("embed_msg_sp", 8, 96, 12, 32),
            "embed_msg_sp_b8_n96_ni12_k32"
        );
    }

    #[test]
    fn real_manifest_if_built() {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert_eq!(m.k, 32);
        assert!(m.entries.len() > 200, "expected full artifact set");
        // Spot-check a few names the coordinator depends on.
        for name in [
            "embed_pre_b1_n24_ni24_k32",
            "embed_msg_b1_n1488_ni248_k32",
            "q_scores_bwd_b8_n24_ni12_k32",
        ] {
            assert!(m.has(name), "{name} missing");
        }
    }
}
