//! Stage executor: lazy-compiles HLO-text artifacts on the PJRT CPU client
//! and runs them with f32 host tensors.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* → HloModuleProto →
//! XlaComputation → PjRtLoadedExecutable; outputs come back as a 1-tuple
//! (the AOT step lowers with return_tuple=True).

use super::manifest::{ArtifactInfo, Manifest};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// A borrowed f32 host tensor (shape + row-major data).
#[derive(Debug, Clone, Copy)]
pub struct HostTensor<'a> {
    pub dims: &'a [usize],
    pub data: &'a [f32],
}

impl<'a> HostTensor<'a> {
    pub fn new(dims: &'a [usize], data: &'a [f32]) -> HostTensor<'a> {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "HostTensor dims {dims:?} do not match data length {}",
            data.len()
        );
        HostTensor { dims, data }
    }

    fn to_buffer(self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        // Direct host->device-buffer upload. Deliberately NOT the
        // Literal-based `execute` path: the vendored crate's C glue leaks
        // every input buffer it creates from a literal (xla_rs.cc
        // `execute`: `buffer.release()` with no delete after Execute), and
        // the literal adds a second host-side copy. `execute_b` with
        // Rust-owned PjRtBuffers fixes both (see EXPERIMENTS.md §Perf).
        Ok(client.buffer_from_host_buffer::<f32>(self.data, self.dims, None)?)
    }
}

/// A stage input: host data (uploaded on the fly) or an already-uploaded
/// device buffer (the §Perf A-reuse optimization — upload the big adjacency
/// shard once per step and share it across every stage that reads it).
pub enum Input<'a> {
    Host(HostTensor<'a>),
    Dev(&'a xla::PjRtBuffer),
}

/// Cumulative execution counters (perf accounting).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub compile_time: Duration,
    pub exec_time: Duration,
    pub h2d_time: Duration,
    pub d2h_time: Duration,
}

/// The PJRT stage runtime. Single-threaded by design (the lockstep engine
/// drives all shards from one thread; see DESIGN.md §3).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<ExecStats>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over the artifact directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        // Quiet XLA's client-lifecycle info logs unless the user opted in.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = ExecStats::default();
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("parse HLO text {}", info.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("XLA compile of {name}"))?,
        );
        self.stats.borrow_mut().compile_time += t0.elapsed();
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warmup so benches don't measure compiles).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Upload a host tensor to a reusable device buffer.
    pub fn upload(&self, dims: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = HostTensor::new(dims, data).to_buffer(&self.client)?;
        self.stats.borrow_mut().h2d_time += t0.elapsed();
        Ok(buf)
    }

    /// Execute artifact `name` with the given inputs; returns one Vec<f32>
    /// per output (the AOT tuple is flattened).
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        let mixed: Vec<Input> = inputs.iter().map(|&t| Input::Host(t)).collect();
        self.execute_in(name, &mixed)
    }

    /// Execute with a mix of host inputs and pre-uploaded device buffers.
    pub fn execute_in(&self, name: &str, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let info: ArtifactInfo = self.manifest.get(name)?.clone();
        let exe = self.executable(name)?;

        let t_h2d = Instant::now();
        // Owned temporaries for host inputs; `refs` borrows both kinds.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (slot, input) in inputs.iter().enumerate() {
            match input {
                Input::Host(t) => {
                    owned.push(
                        t.to_buffer(&self.client)
                            .with_context(|| format!("input {slot} of {name}"))?,
                    );
                }
                Input::Dev(_) => {}
            }
        }
        let mut owned_it = owned.iter();
        for input in inputs {
            match input {
                Input::Host(_) => refs.push(owned_it.next().unwrap()),
                Input::Dev(b) => refs.push(b),
            }
        }
        let h2d = t_h2d.elapsed();

        let t_exec = Instant::now();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .with_context(|| format!("execute {name}"))?;
        let exec = t_exec.elapsed();

        let t_d2h = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {name}"))?;
        let parts = tuple.to_tuple().with_context(|| format!("untuple result of {name}"))?;
        if parts.len() != info.num_outputs {
            bail!(
                "{name}: expected {} outputs, got {}",
                info.num_outputs,
                parts.len()
            );
        }
        let out: Vec<Vec<f32>> =
            parts.into_iter().map(|l| l.to_vec::<f32>()).collect::<xla::Result<_>>()?;
        let d2h = t_d2h.elapsed();

        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.exec_time += exec;
        st.h2d_time += h2d;
        st.d2h_time += d2h;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_name;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    #[test]
    fn host_tensor_validates_shape() {
        let data = vec![0.0f32; 6];
        let _ = HostTensor::new(&[2, 3], &data);
        let r = std::panic::catch_unwind(|| {
            let d = vec![0.0f32; 5];
            let _ = HostTensor::new(&[2, 3], &d);
        });
        assert!(r.is_err());
    }

    #[test]
    fn q_sum_stage_executes() {
        let Some(rt) = runtime() else { return };
        // q_sum: embed [B,K,NI] -> [B,K] (row sums over NI).
        let (b, k, ni) = (1usize, 32usize, 12usize);
        let name = artifact_name("q_sum", b, 24, ni, k);
        let embed: Vec<f32> = (0..b * k * ni).map(|i| (i % 5) as f32).collect();
        let out = rt.execute(&name, &[HostTensor::new(&[b, k, ni], &embed)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b * k);
        for kk in 0..k {
            let want: f32 = (0..ni).map(|j| ((kk * ni + j) % 5) as f32).sum();
            assert!((out[0][kk] - want).abs() < 1e-4, "k={kk}");
        }
        assert_eq!(rt.stats().executions, 1);
    }

    #[test]
    fn embed_msg_matches_manual_bmm() {
        let Some(rt) = runtime() else { return };
        let (b, k, ni, n) = (1usize, 32usize, 12usize, 24usize);
        let name = artifact_name("embed_msg", b, n, ni, k);
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        let embed: Vec<f32> = (0..b * k * ni).map(|_| rng.next_normal()).collect();
        let a: Vec<f32> = (0..b * ni * n).map(|_| (rng.next_f32() < 0.2) as u32 as f32).collect();
        let out = rt
            .execute(
                &name,
                &[HostTensor::new(&[b, k, ni], &embed), HostTensor::new(&[b, ni, n], &a)],
            )
            .unwrap();
        // manual bmm
        let mut want = vec![0.0f32; b * k * n];
        for kk in 0..k {
            for j in 0..ni {
                let e = embed[kk * ni + j];
                if e == 0.0 {
                    continue;
                }
                for nn in 0..n {
                    want[kk * n + nn] += e * a[j * n + nn];
                }
            }
        }
        let diff = crate::util::max_abs_diff(&out[0], &want);
        assert!(diff < 1e-3, "diff {diff}");
    }

    #[test]
    fn missing_artifact_is_informative() {
        let Some(rt) = runtime() else { return };
        let err = rt.execute("embed_msg_b9_n24_ni24_k32", &[]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("configs.py"), "{msg}");
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(rt) = runtime() else { return };
        let name = artifact_name("q_sum", 1, 24, 24, 32);
        rt.warm(&name).unwrap();
        let c1 = rt.compiled_count();
        rt.warm(&name).unwrap();
        assert_eq!(rt.compiled_count(), c1);
    }
}
