//! Stage executor: lazy-compiles HLO-text artifacts on the PJRT CPU client
//! and runs them with f32 host tensors.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* → HloModuleProto →
//! XlaComputation → PjRtLoadedExecutable; outputs come back as a 1-tuple
//! (the AOT step lowers with return_tuple=True).

use super::manifest::{ArtifactInfo, Manifest};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// A borrowed f32 host tensor (shape + row-major data).
#[derive(Debug, Clone, Copy)]
pub struct HostTensor<'a> {
    /// Row-major tensor shape.
    pub dims: &'a [usize],
    /// Borrowed row-major f32 data.
    pub data: &'a [f32],
}

impl<'a> HostTensor<'a> {
    /// Wrap a shape + data slice (lengths must agree).
    pub fn new(dims: &'a [usize], data: &'a [f32]) -> HostTensor<'a> {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "HostTensor dims {dims:?} do not match data length {}",
            data.len()
        );
        HostTensor { dims, data }
    }

    fn to_buffer(self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        // Direct host->device-buffer upload. Deliberately NOT the
        // Literal-based `execute` path: the vendored crate's C glue leaks
        // every input buffer it creates from a literal (xla_rs.cc
        // `execute`: `buffer.release()` with no delete after Execute), and
        // the literal adds a second host-side copy. `execute_b` with
        // Rust-owned PjRtBuffers fixes both (see EXPERIMENTS.md §Perf).
        Ok(client.buffer_from_host_buffer::<f32>(self.data, self.dims, None)?)
    }
}

/// A stage input: host data (uploaded on the fly) or an already-uploaded
/// device buffer (the §Perf A-reuse optimization — upload the big adjacency
/// shard once per step and share it across every stage that reads it).
#[derive(Clone, Copy)]
pub enum Input<'a> {
    /// Host data, uploaded on the fly for this execution.
    Host(HostTensor<'a>),
    /// An already-uploaded device buffer (no transfer).
    Dev(&'a xla::PjRtBuffer),
}

/// Cumulative execution counters (perf accounting).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Stage executions performed.
    pub executions: u64,
    /// Time spent XLA-compiling artifacts.
    pub compile_time: Duration,
    /// Time spent executing stages.
    pub exec_time: Duration,
    /// Time spent in host-to-device uploads.
    pub h2d_time: Duration,
    /// Time spent in device-to-host fetches.
    pub d2h_time: Duration,
    /// Bytes uploaded host→device (stage inputs + explicit uploads).
    pub h2d_bytes: u64,
    /// Bytes fetched device→host (stage outputs + explicit fetches).
    pub d2h_bytes: u64,
    /// Keyed-cache hits: uploads skipped because the (key, generation)
    /// buffer was already device-resident.
    pub cache_hits: u64,
    /// Rank workers replaced after death (rank-parallel pool supervision,
    /// DESIGN.md §11). The runtime itself never sets this; the pool folds
    /// it in when its stats are collected.
    pub restarts: u64,
    /// Time spent recovering the pool (respawn + collective reset + θ
    /// republish). Pool-level, like `restarts`.
    pub recovery_time: Duration,
    /// Bytes sent coordinator→rank over the transport links (requests
    /// and collective fan-out, at canonical wire size — the in-process
    /// transport prices its messages without serializing, DESIGN.md
    /// §12). Pool-level, like `restarts`.
    pub tx_bytes: u64,
    /// Bytes received rank→coordinator over the transport links
    /// (responses and collective deposits). Pool-level.
    pub rx_bytes: u64,
    /// Remote (TCP) rank slots re-filled by a rejoining worker process
    /// (DESIGN.md §12 liveness/rejoin) — the transport-seam sibling of
    /// `restarts`. Pool-level.
    pub remote_restarts: u64,
    /// Liveness deadlines missed: a rank link produced no frame (data or
    /// heartbeat) within `--rank-timeout` and was declared dead.
    /// Pool-level.
    pub heartbeats_missed: u64,
    /// Time spent inside the rejoin window waiting for replacement
    /// workers to re-handshake (a subset of `recovery_time`).
    /// Pool-level.
    pub rejoin_time: Duration,
}

impl ExecStats {
    /// Accumulate another snapshot into this one — summing the per-rank
    /// runtimes of the rank-parallel pool into one pack/queue-level figure.
    pub fn add(&mut self, other: &ExecStats) {
        self.executions += other.executions;
        self.compile_time += other.compile_time;
        self.exec_time += other.exec_time;
        self.h2d_time += other.h2d_time;
        self.d2h_time += other.d2h_time;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
        self.cache_hits += other.cache_hits;
        self.restarts += other.restarts;
        self.recovery_time += other.recovery_time;
        self.tx_bytes += other.tx_bytes;
        self.rx_bytes += other.rx_bytes;
        self.remote_restarts += other.remote_restarts;
        self.heartbeats_missed += other.heartbeats_missed;
        self.rejoin_time += other.rejoin_time;
    }

    /// Counter deltas accumulated since `earlier` (snapshot arithmetic for
    /// per-solve / per-pack transfer accounting). Saturating throughout, so
    /// a `reset_stats` between the snapshots yields zeros, not underflow.
    pub fn since(&self, earlier: &ExecStats) -> ExecStats {
        ExecStats {
            executions: self.executions.saturating_sub(earlier.executions),
            compile_time: self.compile_time.saturating_sub(earlier.compile_time),
            exec_time: self.exec_time.saturating_sub(earlier.exec_time),
            h2d_time: self.h2d_time.saturating_sub(earlier.h2d_time),
            d2h_time: self.d2h_time.saturating_sub(earlier.d2h_time),
            h2d_bytes: self.h2d_bytes.saturating_sub(earlier.h2d_bytes),
            d2h_bytes: self.d2h_bytes.saturating_sub(earlier.d2h_bytes),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            restarts: self.restarts.saturating_sub(earlier.restarts),
            recovery_time: self.recovery_time.saturating_sub(earlier.recovery_time),
            tx_bytes: self.tx_bytes.saturating_sub(earlier.tx_bytes),
            rx_bytes: self.rx_bytes.saturating_sub(earlier.rx_bytes),
            remote_restarts: self.remote_restarts.saturating_sub(earlier.remote_restarts),
            heartbeats_missed: self
                .heartbeats_missed
                .saturating_sub(earlier.heartbeats_missed),
            rejoin_time: self.rejoin_time.saturating_sub(earlier.rejoin_time),
        }
    }
}

/// The PJRT stage runtime. Single-threaded by design (the lockstep engine
/// drives all shards from one thread; see DESIGN.md §3).
pub struct Runtime {
    client: xla::PjRtClient,
    /// The artifact manifest this runtime serves.
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<ExecStats>,
    /// Named, generation-tracked device buffers (the device-residency
    /// layer): `upload_keyed` with a matching (key, generation) skips the
    /// h2d entirely and returns the cached buffer.
    bufs: RefCell<HashMap<String, (u64, Vec<usize>, Rc<xla::PjRtBuffer>)>>,
    /// Monotonic id source for `DeviceState` key namespaces.
    next_id: std::cell::Cell<u64>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over the artifact directory.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        // Quiet XLA's client-lifecycle info logs unless the user opted in.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
            bufs: RefCell::new(HashMap::new()),
            next_id: std::cell::Cell::new(0),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Snapshot of the cumulative execution counters.
    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    /// Zero the cumulative execution counters.
    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = ExecStats::default();
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self.manifest.get(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("parse HLO text {}", info.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("XLA compile of {name}"))?,
        );
        self.stats.borrow_mut().compile_time += t0.elapsed();
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (warmup so benches don't measure compiles).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Upload a host tensor to a reusable device buffer.
    pub fn upload(&self, dims: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = HostTensor::new(dims, data).to_buffer(&self.client)?;
        let mut st = self.stats.borrow_mut();
        st.h2d_time += t0.elapsed();
        st.h2d_bytes += 4 * data.len() as u64;
        Ok(buf)
    }

    /// Allocate a fresh key namespace for a device-state owner (buffers are
    /// registered as `"ds<id>/<name>"`, so eviction by prefix is safe even
    /// with several live `DeviceState`s).
    pub fn alloc_state_id(&self) -> u64 {
        let id = self.next_id.get();
        self.next_id.set(id + 1);
        id
    }

    /// Upload into the named, generation-tracked buffer cache. If `key` is
    /// already resident at `generation`, the upload is skipped (a cache hit)
    /// and the existing device buffer is returned; otherwise the data is
    /// uploaded and replaces whatever generation the key held. A hit
    /// asserts the dims match the resident buffer — a caller that changes
    /// shape without bumping the generation gets a clear panic here
    /// instead of an opaque XLA shape error downstream.
    pub fn upload_keyed(
        &self,
        key: &str,
        generation: u64,
        dims: &[usize],
        data: &[f32],
    ) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some((gen, cached_dims, buf)) = self.bufs.borrow().get(key) {
            if *gen == generation {
                assert_eq!(
                    cached_dims.as_slice(),
                    dims,
                    "keyed buffer '{key}' hit at generation {generation} with a different shape"
                );
                self.stats.borrow_mut().cache_hits += 1;
                return Ok(buf.clone());
            }
        }
        let buf = Rc::new(self.upload(dims, data)?);
        self.bufs.borrow_mut().insert(key.to_string(), (generation, dims.to_vec(), buf.clone()));
        Ok(buf)
    }

    /// Register an already-device-resident buffer (e.g. a stage output that
    /// replaces a cached input, like the masked adjacency) under a key;
    /// `dims` is its shape (for the hit-time shape check).
    pub fn put_keyed(
        &self,
        key: &str,
        generation: u64,
        dims: &[usize],
        buf: xla::PjRtBuffer,
    ) -> Rc<xla::PjRtBuffer> {
        let buf = Rc::new(buf);
        self.bufs.borrow_mut().insert(key.to_string(), (generation, dims.to_vec(), buf.clone()));
        buf
    }

    /// Generation currently resident for `key`, if any.
    pub fn keyed_generation(&self, key: &str) -> Option<u64> {
        self.bufs.borrow().get(key).map(|(gen, _, _)| *gen)
    }

    /// Drop every cached buffer whose key starts with `prefix`; returns how
    /// many entries were evicted.
    pub fn evict_keyed(&self, prefix: &str) -> usize {
        let mut bufs = self.bufs.borrow_mut();
        let before = bufs.len();
        bufs.retain(|k, _| !k.starts_with(prefix));
        before - bufs.len()
    }

    /// Number of live keyed device buffers.
    pub fn keyed_count(&self) -> usize {
        self.bufs.borrow().len()
    }

    /// Total f32 payload bytes of the live keyed device buffers — the
    /// device-resident working set a warm service holds between requests
    /// (surfaced by `oggm serve` and `bench_queue`).
    pub fn keyed_bytes(&self) -> u64 {
        self.bufs
            .borrow()
            .values()
            .map(|(_, dims, _)| 4 * dims.iter().product::<usize>() as u64)
            .sum()
    }

    /// Fetch a device buffer to host (d2h accounted).
    pub fn fetch(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let out: Vec<f32> = buf.to_literal_sync()?.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.d2h_time += t0.elapsed();
        st.d2h_bytes += 4 * out.len() as u64;
        Ok(out)
    }

    /// Execute artifact `name` with the given inputs; returns one Vec<f32>
    /// per output (the AOT tuple is flattened).
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        let mixed: Vec<Input> = inputs.iter().map(|&t| Input::Host(t)).collect();
        self.execute_in(name, &mixed)
    }

    /// Execute with a mix of host inputs and pre-uploaded device buffers.
    pub fn execute_in(&self, name: &str, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let info: ArtifactInfo = self.manifest.get(name)?.clone();
        let exe = self.executable(name)?;
        let (owned, h2d, h2d_bytes) = self.upload_hosts(name, inputs)?;
        let refs = input_refs(inputs, &owned);

        let t_exec = Instant::now();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .with_context(|| format!("execute {name}"))?;
        let exec = t_exec.elapsed();

        let t_d2h = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {name}"))?;
        let parts = tuple.to_tuple().with_context(|| format!("untuple result of {name}"))?;
        if parts.len() != info.num_outputs {
            bail!(
                "{name}: expected {} outputs, got {}",
                info.num_outputs,
                parts.len()
            );
        }
        let out: Vec<Vec<f32>> =
            parts.into_iter().map(|l| l.to_vec::<f32>()).collect::<xla::Result<_>>()?;
        let d2h = t_d2h.elapsed();

        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.exec_time += exec;
        st.h2d_time += h2d;
        st.d2h_time += d2h;
        st.h2d_bytes += h2d_bytes;
        st.d2h_bytes += 4 * out.iter().map(|o| o.len() as u64).sum::<u64>();
        Ok(out)
    }

    /// Execute and keep the outputs device-resident: returns one
    /// `PjRtBuffer` per output (untupled on device) with NO d2h. This is
    /// the hot-path variant — chain an output into the next stage via
    /// `Input::Dev`, and bring it to host only at collectives/final scores
    /// with `fetch`.
    pub fn execute_d(&self, name: &str, inputs: &[Input]) -> Result<Vec<xla::PjRtBuffer>> {
        let info: ArtifactInfo = self.manifest.get(name)?.clone();
        let exe = self.executable(name)?;
        let (owned, h2d, h2d_bytes) = self.upload_hosts(name, inputs)?;
        let refs = input_refs(inputs, &owned);

        let t_exec = Instant::now();
        let result = exe
            .execute_untupled::<&xla::PjRtBuffer>(&refs)
            .with_context(|| format!("execute {name}"))?;
        let exec = t_exec.elapsed();

        let mut devices = result.into_iter();
        let outs: Vec<xla::PjRtBuffer> =
            devices.next().map(|v| v.into_iter().collect()).unwrap_or_default();
        if outs.len() != info.num_outputs {
            bail!("{name}: expected {} outputs, got {}", info.num_outputs, outs.len());
        }

        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.exec_time += exec;
        st.h2d_time += h2d;
        st.h2d_bytes += h2d_bytes;
        Ok(outs)
    }

    /// Upload every `Input::Host` tensor as an owned device buffer (in input
    /// order); returns (uploads, h2d time, h2d bytes).
    fn upload_hosts(
        &self,
        name: &str,
        inputs: &[Input],
    ) -> Result<(Vec<xla::PjRtBuffer>, Duration, u64)> {
        let t_h2d = Instant::now();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut h2d_bytes = 0u64;
        for (slot, input) in inputs.iter().enumerate() {
            if let Input::Host(t) = input {
                owned.push(
                    t.to_buffer(&self.client)
                        .with_context(|| format!("input {slot} of {name}"))?,
                );
                h2d_bytes += 4 * t.data.len() as u64;
            }
        }
        Ok((owned, t_h2d.elapsed(), h2d_bytes))
    }
}

/// Interleave freshly uploaded host buffers with the caller's device
/// buffers, restoring the stage's input order.
fn input_refs<'a>(
    inputs: &'a [Input<'a>],
    owned: &'a [xla::PjRtBuffer],
) -> Vec<&'a xla::PjRtBuffer> {
    let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
    let mut owned_it = owned.iter();
    for input in inputs {
        match input {
            Input::Host(_) => refs.push(owned_it.next().unwrap()),
            Input::Dev(b) => refs.push(b),
        }
    }
    refs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_name;

    fn runtime() -> Option<Runtime> {
        if !std::path::Path::new("artifacts/manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new("artifacts").unwrap())
    }

    #[test]
    fn exec_stats_since_subtracts_counters() {
        let mut early = ExecStats::default();
        early.executions = 3;
        early.h2d_bytes = 1000;
        early.d2h_bytes = 200;
        early.cache_hits = 1;
        early.exec_time = Duration::from_millis(5);
        let mut late = early;
        late.executions += 7;
        late.h2d_bytes += 4096;
        late.d2h_bytes += 512;
        late.cache_hits += 4;
        late.exec_time += Duration::from_millis(20);
        let d = late.since(&early);
        assert_eq!(d.executions, 7);
        assert_eq!(d.h2d_bytes, 4096);
        assert_eq!(d.d2h_bytes, 512);
        assert_eq!(d.cache_hits, 4);
        assert_eq!(d.exec_time, Duration::from_millis(20));
        // A snapshot minus itself is all-zero.
        let z = late.since(&late);
        assert_eq!(z.executions, 0);
        assert_eq!(z.h2d_bytes + z.d2h_bytes + z.cache_hits, 0);
    }

    #[test]
    fn host_tensor_validates_shape() {
        let data = vec![0.0f32; 6];
        let _ = HostTensor::new(&[2, 3], &data);
        let r = std::panic::catch_unwind(|| {
            let d = vec![0.0f32; 5];
            let _ = HostTensor::new(&[2, 3], &d);
        });
        assert!(r.is_err());
    }

    #[test]
    fn q_sum_stage_executes() {
        let Some(rt) = runtime() else { return };
        // q_sum: embed [B,K,NI] -> [B,K] (row sums over NI).
        let (b, k, ni) = (1usize, 32usize, 12usize);
        let name = artifact_name("q_sum", b, 24, ni, k);
        let embed: Vec<f32> = (0..b * k * ni).map(|i| (i % 5) as f32).collect();
        let out = rt.execute(&name, &[HostTensor::new(&[b, k, ni], &embed)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b * k);
        for kk in 0..k {
            let want: f32 = (0..ni).map(|j| ((kk * ni + j) % 5) as f32).sum();
            assert!((out[0][kk] - want).abs() < 1e-4, "k={kk}");
        }
        let st = rt.stats();
        assert_eq!(st.executions, 1);
        assert_eq!(st.h2d_bytes, 4 * (b * k * ni) as u64);
        assert_eq!(st.d2h_bytes, 4 * (b * k) as u64);
    }

    #[test]
    fn keyed_cache_hits_and_evicts() {
        let Some(rt) = runtime() else { return };
        let data = vec![1.0f32; 8];
        rt.upload_keyed("t/x", 0, &[8], &data).unwrap();
        let before = rt.stats();
        rt.upload_keyed("t/x", 0, &[8], &data).unwrap();
        let after = rt.stats();
        assert_eq!(after.cache_hits, before.cache_hits + 1);
        assert_eq!(after.h2d_bytes, before.h2d_bytes, "cache hit must not re-upload");
        // A new generation re-uploads and replaces (same key: count stable).
        let count = rt.keyed_count();
        rt.upload_keyed("t/x", 1, &[8], &data).unwrap();
        assert_eq!(rt.keyed_generation("t/x"), Some(1));
        assert_eq!(rt.keyed_count(), count);
        assert_eq!(rt.stats().h2d_bytes, after.h2d_bytes + 32);
        assert_eq!(rt.evict_keyed("t/"), 1);
        assert_eq!(rt.keyed_generation("t/x"), None);
        assert_eq!(rt.keyed_count(), count - 1);
    }

    #[test]
    fn execute_d_chains_without_d2h() {
        let Some(rt) = runtime() else { return };
        // q_sum twice: once via execute (host round-trip), once via
        // execute_d keeping the input device-resident — byte counters must
        // show zero d2h for the device variant until fetch.
        let (b, k, ni) = (1usize, 32usize, 12usize);
        let name = artifact_name("q_sum", b, 24, ni, k);
        let embed: Vec<f32> = (0..b * k * ni).map(|i| (i % 7) as f32).collect();
        let want = rt.execute(&name, &[HostTensor::new(&[b, k, ni], &embed)]).unwrap();

        let e_buf = rt.upload(&[b, k, ni], &embed).unwrap();
        let before = rt.stats();
        let outs = rt.execute_d(&name, &[Input::Dev(&e_buf)]).unwrap();
        assert_eq!(outs.len(), 1);
        let mid = rt.stats();
        assert_eq!(mid.d2h_bytes, before.d2h_bytes, "execute_d must not fetch");
        assert_eq!(mid.h2d_bytes, before.h2d_bytes, "all inputs were device-resident");
        let got = rt.fetch(&outs[0]).unwrap();
        assert_eq!(rt.stats().d2h_bytes, mid.d2h_bytes + 4 * (b * k) as u64);
        assert_eq!(got, want[0], "device-chained output differs from host path");
    }

    #[test]
    fn embed_msg_matches_manual_bmm() {
        let Some(rt) = runtime() else { return };
        let (b, k, ni, n) = (1usize, 32usize, 12usize, 24usize);
        let name = artifact_name("embed_msg", b, n, ni, k);
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        let embed: Vec<f32> = (0..b * k * ni).map(|_| rng.next_normal()).collect();
        let a: Vec<f32> = (0..b * ni * n).map(|_| (rng.next_f32() < 0.2) as u32 as f32).collect();
        let out = rt
            .execute(
                &name,
                &[HostTensor::new(&[b, k, ni], &embed), HostTensor::new(&[b, ni, n], &a)],
            )
            .unwrap();
        // manual bmm
        let mut want = vec![0.0f32; b * k * n];
        for kk in 0..k {
            for j in 0..ni {
                let e = embed[kk * ni + j];
                if e == 0.0 {
                    continue;
                }
                for nn in 0..n {
                    want[kk * n + nn] += e * a[j * n + nn];
                }
            }
        }
        let diff = crate::util::max_abs_diff(&out[0], &want);
        assert!(diff < 1e-3, "diff {diff}");
    }

    #[test]
    fn missing_artifact_is_informative() {
        let Some(rt) = runtime() else { return };
        let err = rt.execute("embed_msg_b9_n24_ni24_k32", &[]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("configs.py"), "{msg}");
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(rt) = runtime() else { return };
        let name = artifact_name("q_sum", 1, 24, 24, 32);
        rt.warm(&name).unwrap();
        let c1 = rt.compiled_count();
        rt.warm(&name).unwrap();
        assert_eq!(rt.compiled_count(), c1);
    }
}
