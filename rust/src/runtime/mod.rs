//! PJRT runtime: loads the AOT-compiled HLO-text stages emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path: the manifest + HLO files are the entire
//! interface between the build step and the coordinator (DESIGN.md §2).

pub mod manifest;
pub mod exec;

pub use exec::{ExecStats, HostTensor, Input, Runtime};
pub use manifest::{ArtifactInfo, Manifest};

/// Artifact naming convention; must mirror python/compile/configs.py.
pub fn artifact_name(stage: &str, b: usize, n: usize, ni: usize, k: usize) -> String {
    format!("{stage}_b{b}_n{n}_ni{ni}_k{k}")
}

#[cfg(test)]
mod tests {
    #[test]
    fn naming_matches_python() {
        assert_eq!(
            super::artifact_name("embed_msg", 1, 24, 12, 32),
            "embed_msg_b1_n24_ni12_k32"
        );
    }
}
