//! PJRT runtime: loads the AOT-compiled HLO-text stages emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path: the manifest + HLO files are the entire
//! interface between the build step and the coordinator (DESIGN.md §2).

/// Artifact manifest parsing + sparse shape lookups.
pub mod manifest;
/// Stage executor over the PJRT CPU client.
pub mod exec;

pub use exec::{ExecStats, HostTensor, Input, Runtime};
pub use manifest::{ArtifactInfo, Manifest};

/// Artifact naming convention; must mirror python/compile/configs.py.
pub fn artifact_name(stage: &str, b: usize, n: usize, ni: usize, k: usize) -> String {
    format!("{stage}_b{b}_n{n}_ni{ni}_k{k}")
}

/// Name of the N-free sparse stage-1 artifact (`embed_pre_sp`): the (n)
/// slot is pinned to 0 because the stage consumes the degree vector
/// instead of an N-wide adjacency (DESIGN.md §7).
pub fn sparse_pre_name(stage: &str, b: usize, ni: usize, k: usize) -> String {
    artifact_name(stage, b, 0, ni, k)
}

/// Name of a sparse message-tile artifact (`embed_msg_sp`/`_bwd`): the
/// (n, ni) slots carry (edge capacity EC, node chunk NC) — the shape is
/// N-free by construction (DESIGN.md §7).
pub fn sparse_msg_name(stage: &str, b: usize, edge_cap: usize, chunk: usize, k: usize) -> String {
    artifact_name(stage, b, edge_cap, chunk, k)
}

#[cfg(test)]
mod tests {
    #[test]
    fn naming_matches_python() {
        assert_eq!(
            super::artifact_name("embed_msg", 1, 24, 12, 32),
            "embed_msg_b1_n24_ni12_k32"
        );
    }
}
