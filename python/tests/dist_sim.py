"""Python simulation of the Rust coordinator's distributed orchestration.

This composes the per-shard stage functions with explicit collectives
(all-reduce / all-gather / slice) exactly as rust/src/coordinator/{fwd,bwd}.rs
does. The tests assert it matches the monolithic model + jax.grad — the core
design validation for the hand-rolled distributed backprop. It is also the
executable specification the Rust implementation mirrors.
"""

import jax.numpy as jnp

from compile import model, stages


def shard(x, p, axis):
    """Split along `axis` into p equal parts (row partitioning, Fig. 2)."""
    return jnp.split(x, p, axis=axis)


def dist_forward(params, a, s, c, p, layers=model.L, save=False):
    """Distributed Alg. 2 + Alg. 3 over p simulated shards.

    a [B,N,N], s,c [B,N]. Returns scores [B,N] (and saved activations for
    the backward pass when save=True).
    """
    a_i = shard(a, p, axis=1)      # each [B,NI,N]
    s_i = shard(s, p, axis=1)
    c_i = shard(c, p, axis=1)

    pre = [stages.embed_pre(params["theta1"], params["theta2"], params["theta3"],
                            s_i[i], a_i[i]) for i in range(p)]
    embed = [jnp.zeros_like(pre[i]) for i in range(p)]          # Alg. 2 line 3
    acts = {"pre": pre, "embed_in": [], "nbr_slice": []}
    for _ in range(layers):
        if save:
            acts["embed_in"].append(list(embed))
        partial = [stages.embed_msg(embed[i], a_i[i], use_pallas=False)
                   for i in range(p)]
        nbr = sum(partial)                                      # ALL-REDUCE (line 12)
        nbr_i = shard(nbr, p, axis=2)                           # local column slice
        if save:
            acts["nbr_slice"].append(list(nbr_i))
        embed = [stages.embed_combine(params["theta4"], pre[i], nbr_i[i],
                                      use_pallas=False) for i in range(p)]
    sums = [stages.q_sum(embed[i]) for i in range(p)]
    sum_all = sum(sums)                                         # ALL-REDUCE (Alg.3 line 5)
    scores = [stages.q_scores(params["theta5"], params["theta6"], params["theta7"],
                              embed[i], c_i[i], sum_all) for i in range(p)]
    out = jnp.concatenate(scores, axis=1)                       # ALL-GATHER (Alg.4 line 6)
    if save:
        acts["embed_final"] = embed
        acts["sum_all"] = sum_all
        acts["a_i"], acts["s_i"], acts["c_i"] = a_i, s_i, c_i
        return out, acts
    return out


def dist_backward(params, acts, scores, onehot, targets, p, layers=model.L):
    """Distributed backward pass mirroring rust/src/coordinator/bwd.rs.

    Returns the all-reduced parameter-gradient pytree.
    """
    b = scores.shape[0]
    onehot_i = shard(onehot, p, axis=1)
    scores_i = shard(scores, p, axis=1)

    # Loss adjoint: q_sa needs an ALL-REDUCE of per-shard partial sums.
    q_sa = sum(jnp.sum(scores_i[i] * onehot_i[i], axis=1) for i in range(p))
    d_qsa = 2.0 / b * (q_sa - targets)                          # [B]
    d_scores = [d_qsa[:, None] * onehot_i[i] for i in range(p)]

    zeros_like = lambda name: jnp.zeros_like(params[name])
    g = {name: zeros_like(name) for name in model.PARAM_ORDER}

    # Stage 5 adjoint.
    d_embed, d_sum_parts = [], []
    for i in range(p):
        d5, d6, d7, d_e, d_sa = stages.q_scores_bwd(
            params["theta5"], params["theta6"], params["theta7"],
            acts["embed_final"][i], acts["c_i"][i], acts["sum_all"], d_scores[i])
        g["theta5"] += d5
        g["theta6"] += d6
        g["theta7"] += d7
        d_embed.append(d_e)
        d_sum_parts.append(d_sa)
    # sum_all was an all-reduce; adjoint: ALL-REDUCE the cotangents, then the
    # q_sum broadcast adjoint adds d_sum_all to every column.
    d_sum_all = sum(d_sum_parts)
    d_embed = [d_embed[i] + d_sum_all[:, :, None] for i in range(p)]

    d_pre_acc = [jnp.zeros_like(acts["pre"][i]) for i in range(p)]
    for l in reversed(range(layers)):
        d_nbr = []
        for i in range(p):
            d4, d_pre, d_nb = stages.embed_combine_bwd(
                params["theta4"], acts["pre"][i], acts["nbr_slice"][l][i], d_embed[i])
            g["theta4"] += d4
            d_pre_acc[i] += d_pre
            d_nbr.append(d_nb)
        # nbr slice consumed the all-reduced tensor; adjoint: ALL-GATHER the
        # slices into [B,K,N], identical on every shard (all-reduce adjoint).
        d_partial = jnp.concatenate(d_nbr, axis=2)
        d_embed = [stages.embed_msg_bwd(acts["a_i"][i], d_partial) for i in range(p)]
        # layer 0's input embedding is the zeros constant; cotangent discarded.

    for i in range(p):
        d1, d2, d3 = stages.embed_pre_bwd(
            params["theta1"], params["theta2"], params["theta3"],
            acts["s_i"][i], acts["a_i"][i], d_pre_acc[i])
        g["theta1"] += d1
        g["theta2"] += d2
        g["theta3"] += d3
    return g  # conceptually followed by the gradient ALL-REDUCE (already summed)


def dist_loss_and_grad(params, a, s, c, onehot, targets, p, layers=model.L):
    scores, acts = dist_forward(params, a, s, c, p, layers, save=True)
    q_sa = jnp.sum(scores * onehot, axis=1)
    loss = jnp.mean((q_sa - targets) ** 2)
    g = dist_backward(params, acts, scores, onehot, targets, p, layers)
    return loss, g
