"""Python simulation of the Rust coordinator's distributed orchestration.

This composes the per-shard stage functions with explicit collectives
(all-reduce / all-gather / slice) exactly as rust/src/coordinator/{fwd,bwd}.rs
does. The tests assert it matches the monolithic model + jax.grad — the core
design validation for the hand-rolled distributed backprop. It is also the
executable specification the Rust implementation mirrors.
"""

import math

import jax.numpy as jnp
import numpy as np

from compile import model, stages


def shard(x, p, axis):
    """Split along `axis` into p equal parts (row partitioning, Fig. 2)."""
    return jnp.split(x, p, axis=axis)


# ---------------------------------------------------------------- sparse path
# Executable specification of the CSR compute path (DESIGN.md §7): edge
# tiling, chunked gather/segment-sum message passing, and its backward.
# rust/src/coordinator/{shard,fwd,bwd}.rs mirror this exactly.


def build_tiles(a_i, nc, caps):
    """Tile one shard's sub-adjacency [B,NI,N] into padded edge lists.

    Edges are enumerated batch-element-major, then row-major (the order
    SparseShard::from_graphs uses), bucketed by (source chunk sc = r // nc,
    destination chunk dc = u // nc), and each bucket is split into tiles of
    the smallest capacity from `caps` that fits the remainder (overflow
    chains into sibling tiles of the largest capacity). Returns a list of
    (sc, dc, src[EC], dst[EC], w[B,EC]) with chunk-local f32 indices and a
    per-batch-element live mask.
    """
    A = np.asarray(a_i)
    b, ni, n = A.shape
    buckets = {}
    for g in range(b):
        for r in range(ni):
            for u in np.nonzero(A[g, r])[0]:
                buckets.setdefault((r // nc, int(u) // nc), []).append((g, r % nc, int(u) % nc))
    caps = sorted(caps)
    tiles = []
    for (sc, dc) in sorted(buckets):
        edges = buckets[(sc, dc)]
        while edges:
            cap = next((c for c in caps if c >= len(edges)), caps[-1])
            take, edges = edges[:cap], edges[cap:]
            src = np.zeros(cap, np.float32)
            dst = np.zeros(cap, np.float32)
            w = np.zeros((b, cap), np.float32)
            for pos, (g, rl, ul) in enumerate(take):
                src[pos] = rl
                dst[pos] = ul
                w[g, pos] = 1.0
            tiles.append((sc, dc, src, dst, w))
    return tiles


def sparse_msg(embed_i, tiles, n, nc):
    """Shard-local message partial [B,K,N] from tiled embed_msg_sp calls.

    Pads the source embedding to a whole number of chunks (padding rows are
    never referenced by live edges) and clips the final destination chunk
    at N — the same boundary handling the Rust coordinator performs.
    """
    e = np.asarray(embed_i)
    b, k, ni = e.shape
    nsc = math.ceil(ni / nc)
    emb = np.zeros((b, k, nsc * nc), np.float32)
    emb[:, :, :ni] = e
    partial = np.zeros((b, k, n), np.float32)
    for sc, dc, src, dst, w in tiles:
        chunk = jnp.asarray(emb[:, :, sc * nc:(sc + 1) * nc])
        out = np.asarray(stages.embed_msg_sp(chunk, jnp.asarray(src), jnp.asarray(dst),
                                             jnp.asarray(w)))
        hi = min(n, (dc + 1) * nc)
        partial[:, :, dc * nc:hi] += out[:, :, :hi - dc * nc]
    return jnp.asarray(partial)


def sparse_msg_bwd(d_partial, tiles, ni, nc):
    """Adjoint of `sparse_msg`: d_embed [B,K,NI] from the [B,K,N] cotangent."""
    d = np.asarray(d_partial)
    b, k, n = d.shape
    ndc = math.ceil(n / nc)
    dpad = np.zeros((b, k, ndc * nc), np.float32)
    dpad[:, :, :n] = d
    nsc = math.ceil(ni / nc)
    d_emb = np.zeros((b, k, nsc * nc), np.float32)
    for sc, dc, src, dst, w in tiles:
        chunk = jnp.asarray(dpad[:, :, dc * nc:(dc + 1) * nc])
        out = np.asarray(stages.embed_msg_sp_bwd(chunk, jnp.asarray(src), jnp.asarray(dst),
                                                 jnp.asarray(w)))
        d_emb[:, :, sc * nc:(sc + 1) * nc] += out
    return jnp.asarray(d_emb[:, :, :ni])


def dist_forward_sparse(params, a, s, c, p, nc=12, caps=(96, 768),
                        layers=model.L, save=False):
    """`dist_forward` on the sparse CSR path (DESIGN.md §7).

    The dense a [B,N,N] is reference input only — the compute consumes edge
    tiles and the degree vector, never an N-wide adjacency tensor.
    """
    a_i = shard(a, p, axis=1)
    s_i = shard(s, p, axis=1)
    c_i = shard(c, p, axis=1)
    n = a.shape[1]
    ni = n // p
    deg_i = [jnp.sum(a_i[i], axis=2) for i in range(p)]
    tiles_i = [build_tiles(a_i[i], nc, caps) for i in range(p)]

    pre = [stages.embed_pre_sp(params["theta1"], params["theta2"], params["theta3"],
                               s_i[i], deg_i[i]) for i in range(p)]
    embed = [jnp.zeros_like(pre[i]) for i in range(p)]
    acts = {"pre": pre, "embed_in": [], "nbr_slice": []}
    for _ in range(layers):
        if save:
            acts["embed_in"].append(list(embed))
        partial = [sparse_msg(embed[i], tiles_i[i], n, nc) for i in range(p)]
        nbr = sum(partial)                                      # ALL-REDUCE
        nbr_i = shard(nbr, p, axis=2)
        if save:
            acts["nbr_slice"].append(list(nbr_i))
        embed = [stages.embed_combine(params["theta4"], pre[i], nbr_i[i],
                                      use_pallas=False) for i in range(p)]
    sums = [stages.q_sum(embed[i]) for i in range(p)]
    sum_all = sum(sums)                                         # ALL-REDUCE
    scores = [stages.q_scores(params["theta5"], params["theta6"], params["theta7"],
                              embed[i], c_i[i], sum_all) for i in range(p)]
    out = jnp.concatenate(scores, axis=1)                       # ALL-GATHER
    if save:
        acts["embed_final"] = embed
        acts["sum_all"] = sum_all
        acts["s_i"], acts["c_i"] = s_i, c_i
        acts["deg_i"], acts["tiles_i"], acts["ni"], acts["nc"] = deg_i, tiles_i, ni, nc
        return out, acts
    return out


def dist_backward_sparse(params, acts, scores, onehot, targets, p, layers=model.L):
    """Distributed backward on the sparse path (tile-transposed msg VJP)."""
    b = scores.shape[0]
    onehot_i = shard(onehot, p, axis=1)
    scores_i = shard(scores, p, axis=1)
    q_sa = sum(jnp.sum(scores_i[i] * onehot_i[i], axis=1) for i in range(p))
    d_qsa = 2.0 / b * (q_sa - targets)
    d_scores = [d_qsa[:, None] * onehot_i[i] for i in range(p)]

    g = {name: jnp.zeros_like(params[name]) for name in model.PARAM_ORDER}
    d_embed, d_sum_parts = [], []
    for i in range(p):
        d5, d6, d7, d_e, d_sa = stages.q_scores_bwd(
            params["theta5"], params["theta6"], params["theta7"],
            acts["embed_final"][i], acts["c_i"][i], acts["sum_all"], d_scores[i])
        g["theta5"] += d5
        g["theta6"] += d6
        g["theta7"] += d7
        d_embed.append(d_e)
        d_sum_parts.append(d_sa)
    d_sum_all = sum(d_sum_parts)
    d_embed = [d_embed[i] + d_sum_all[:, :, None] for i in range(p)]

    d_pre_acc = [jnp.zeros_like(acts["pre"][i]) for i in range(p)]
    for l in reversed(range(layers)):
        d_nbr = []
        for i in range(p):
            d4, d_pre, d_nb = stages.embed_combine_bwd(
                params["theta4"], acts["pre"][i], acts["nbr_slice"][l][i], d_embed[i])
            g["theta4"] += d4
            d_pre_acc[i] += d_pre
            d_nbr.append(d_nb)
        d_partial = jnp.concatenate(d_nbr, axis=2)              # ALL-GATHER
        d_embed = [sparse_msg_bwd(d_partial, acts["tiles_i"][i], acts["ni"], acts["nc"])
                   for i in range(p)]

    for i in range(p):
        d1, d2, d3 = stages.embed_pre_sp_bwd(
            params["theta1"], params["theta2"], params["theta3"],
            acts["s_i"][i], acts["deg_i"][i], d_pre_acc[i])
        g["theta1"] += d1
        g["theta2"] += d2
        g["theta3"] += d3
    return g


def dist_loss_and_grad_sparse(params, a, s, c, onehot, targets, p,
                              layers=model.L, nc=12, caps=(96, 768)):
    scores, acts = dist_forward_sparse(params, a, s, c, p, nc, caps, layers, save=True)
    q_sa = jnp.sum(scores * onehot, axis=1)
    loss = jnp.mean((q_sa - targets) ** 2)
    g = dist_backward_sparse(params, acts, scores, onehot, targets, p, layers)
    return loss, g


def dist_forward(params, a, s, c, p, layers=model.L, save=False):
    """Distributed Alg. 2 + Alg. 3 over p simulated shards.

    a [B,N,N], s,c [B,N]. Returns scores [B,N] (and saved activations for
    the backward pass when save=True).
    """
    a_i = shard(a, p, axis=1)      # each [B,NI,N]
    s_i = shard(s, p, axis=1)
    c_i = shard(c, p, axis=1)

    pre = [stages.embed_pre(params["theta1"], params["theta2"], params["theta3"],
                            s_i[i], a_i[i]) for i in range(p)]
    embed = [jnp.zeros_like(pre[i]) for i in range(p)]          # Alg. 2 line 3
    acts = {"pre": pre, "embed_in": [], "nbr_slice": []}
    for _ in range(layers):
        if save:
            acts["embed_in"].append(list(embed))
        partial = [stages.embed_msg(embed[i], a_i[i], use_pallas=False)
                   for i in range(p)]
        nbr = sum(partial)                                      # ALL-REDUCE (line 12)
        nbr_i = shard(nbr, p, axis=2)                           # local column slice
        if save:
            acts["nbr_slice"].append(list(nbr_i))
        embed = [stages.embed_combine(params["theta4"], pre[i], nbr_i[i],
                                      use_pallas=False) for i in range(p)]
    sums = [stages.q_sum(embed[i]) for i in range(p)]
    sum_all = sum(sums)                                         # ALL-REDUCE (Alg.3 line 5)
    scores = [stages.q_scores(params["theta5"], params["theta6"], params["theta7"],
                              embed[i], c_i[i], sum_all) for i in range(p)]
    out = jnp.concatenate(scores, axis=1)                       # ALL-GATHER (Alg.4 line 6)
    if save:
        acts["embed_final"] = embed
        acts["sum_all"] = sum_all
        acts["a_i"], acts["s_i"], acts["c_i"] = a_i, s_i, c_i
        return out, acts
    return out


def dist_backward(params, acts, scores, onehot, targets, p, layers=model.L):
    """Distributed backward pass mirroring rust/src/coordinator/bwd.rs.

    Returns the all-reduced parameter-gradient pytree.
    """
    b = scores.shape[0]
    onehot_i = shard(onehot, p, axis=1)
    scores_i = shard(scores, p, axis=1)

    # Loss adjoint: q_sa needs an ALL-REDUCE of per-shard partial sums.
    q_sa = sum(jnp.sum(scores_i[i] * onehot_i[i], axis=1) for i in range(p))
    d_qsa = 2.0 / b * (q_sa - targets)                          # [B]
    d_scores = [d_qsa[:, None] * onehot_i[i] for i in range(p)]

    zeros_like = lambda name: jnp.zeros_like(params[name])
    g = {name: zeros_like(name) for name in model.PARAM_ORDER}

    # Stage 5 adjoint.
    d_embed, d_sum_parts = [], []
    for i in range(p):
        d5, d6, d7, d_e, d_sa = stages.q_scores_bwd(
            params["theta5"], params["theta6"], params["theta7"],
            acts["embed_final"][i], acts["c_i"][i], acts["sum_all"], d_scores[i])
        g["theta5"] += d5
        g["theta6"] += d6
        g["theta7"] += d7
        d_embed.append(d_e)
        d_sum_parts.append(d_sa)
    # sum_all was an all-reduce; adjoint: ALL-REDUCE the cotangents, then the
    # q_sum broadcast adjoint adds d_sum_all to every column.
    d_sum_all = sum(d_sum_parts)
    d_embed = [d_embed[i] + d_sum_all[:, :, None] for i in range(p)]

    d_pre_acc = [jnp.zeros_like(acts["pre"][i]) for i in range(p)]
    for l in reversed(range(layers)):
        d_nbr = []
        for i in range(p):
            d4, d_pre, d_nb = stages.embed_combine_bwd(
                params["theta4"], acts["pre"][i], acts["nbr_slice"][l][i], d_embed[i])
            g["theta4"] += d4
            d_pre_acc[i] += d_pre
            d_nbr.append(d_nb)
        # nbr slice consumed the all-reduced tensor; adjoint: ALL-GATHER the
        # slices into [B,K,N], identical on every shard (all-reduce adjoint).
        d_partial = jnp.concatenate(d_nbr, axis=2)
        d_embed = [stages.embed_msg_bwd(acts["a_i"][i], d_partial) for i in range(p)]
        # layer 0's input embedding is the zeros constant; cotangent discarded.

    for i in range(p):
        d1, d2, d3 = stages.embed_pre_bwd(
            params["theta1"], params["theta2"], params["theta3"],
            acts["s_i"][i], acts["a_i"][i], d_pre_acc[i])
        g["theta1"] += d1
        g["theta2"] += d2
        g["theta3"] += d3
    return g  # conceptually followed by the gradient ALL-REDUCE (already summed)


def dist_loss_and_grad(params, a, s, c, onehot, targets, p, layers=model.L):
    scores, acts = dist_forward(params, a, s, c, p, layers, save=True)
    q_sa = jnp.sum(scores * onehot, axis=1)
    loss = jnp.mean((q_sa - targets) ** 2)
    g = dist_backward(params, acts, scores, onehot, targets, p, layers)
    return loss, g
