"""L2 correctness: distributed stage composition vs the monolithic model.

These tests validate the *design* of the Rust coordinator: composing the
per-shard stages with explicit collectives must reproduce the single-device
model (forward) and jax.grad (backward) for every device count P.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model, stages
from compile.aot import _random_instance
import dist_sim


def _setup(b=4, n=24, seed=0):
    key = jax.random.PRNGKey(seed)
    pkey, gkey, akey, tkey = jax.random.split(key, 4)
    params = model.init_params(pkey)
    a, s, c = _random_instance(gkey, b, n)
    idx = jax.random.randint(akey, (b,), 0, n)
    onehot = jax.nn.one_hot(idx, n, dtype=jnp.float32)
    c = jnp.maximum(c, onehot)
    targets = jax.random.normal(tkey, (b,))
    return params, a, s, c, onehot, targets


@pytest.mark.parametrize("p", [1, 2, 3, 4, 6])
def test_dist_forward_matches_monolithic(p):
    params, a, s, c, _, _ = _setup(b=3, n=24)
    mono = model.full_forward(params, a, s, c)
    dist = dist_sim.dist_forward(params, a, s, c, p)
    assert_allclose(np.asarray(dist), np.asarray(mono), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("p", [1, 2, 3, 4, 6])
def test_dist_grad_matches_jax_grad(p):
    params, a, s, c, onehot, targets = _setup(b=4, n=24, seed=3)
    want = model.full_loss_grad(params, a, s, c, onehot, targets)
    loss, got = dist_sim.dist_loss_and_grad(params, a, s, c, onehot, targets, p)
    want_loss = model.full_loss(params, a, s, c, onehot, targets)
    assert abs(float(loss) - float(want_loss)) < 1e-5
    for name in model.PARAM_ORDER:
        assert_allclose(np.asarray(got[name]), np.asarray(want[name]),
                        rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("layers", [1, 2, 3, 4])
def test_layer_count_is_runtime_choice(layers):
    # Stages are per-layer, so any L must compose correctly.
    params, a, s, c, onehot, targets = _setup(b=2, n=24, seed=7)
    mono = model.full_forward(params, a, s, c, layers=layers)
    dist = dist_sim.dist_forward(params, a, s, c, p=3, layers=layers)
    assert_allclose(np.asarray(dist), np.asarray(mono), rtol=1e-5, atol=1e-5)
    want = jax.grad(model.full_loss)(params, a, s, c, onehot, targets, layers)
    _, got = dist_sim.dist_loss_and_grad(params, a, s, c, onehot, targets, 2, layers)
    for name in model.PARAM_ORDER:
        assert_allclose(np.asarray(got[name]), np.asarray(want[name]),
                        rtol=1e-4, atol=1e-5, err_msg=name)


def test_pallas_and_ref_paths_agree_in_composition():
    params, a, s, c, _, _ = _setup(b=2, n=24, seed=9)
    a_i = dist_sim.shard(a, 2, axis=1)
    e = jax.random.normal(jax.random.PRNGKey(1), (2, model.K, 12))
    got = stages.embed_msg(e, a_i[0], use_pallas=True)
    want = stages.embed_msg(e, a_i[0], use_pallas=False)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_padding_nodes_are_inert():
    # Padding with isolated non-candidate nodes must not change real scores.
    params, a, s, c, _, _ = _setup(b=2, n=24, seed=11)
    scores = model.full_forward(params, a, s, c)
    pad = 12
    n = 24
    a_p = jnp.zeros((2, n + pad, n + pad), jnp.float32).at[:, :n, :n].set(a)
    s_p = jnp.zeros((2, n + pad), jnp.float32).at[:, :n].set(s)
    c_p = jnp.zeros((2, n + pad), jnp.float32).at[:, :n].set(c)
    scores_p = model.full_forward(params, a_p, s_p, c_p)
    assert_allclose(np.asarray(scores_p[:, :n]), np.asarray(scores), rtol=1e-5,
                    atol=1e-5)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_a_mask_matches_host_row_col_zeroing(p):
    # The device-resident path patches each shard's adjacency on device with
    # a_mask instead of re-uploading it (DESIGN.md §6); the 0/1-mask multiply
    # must reproduce explicit row/column zeroing BIT-exactly, per shard
    # (rows are shard-local, columns are global — the apply_remove split).
    b, n = 2, 24
    ni = n // p
    key = jax.random.PRNGKey(7)
    a_full, _, _ = _random_instance(key, b, n)
    a_full = np.asarray(a_full)
    removed = [(0, 3), (0, 17), (1, 11)]  # (batch element, global node)
    for shard in range(p):
        row0 = shard * ni
        a = a_full[:, row0:row0 + ni, :]
        row_mask = np.ones((b, ni), np.float32)
        col_mask = np.ones((b, n), np.float32)
        want = a.copy()
        for g, v in removed:
            if row0 <= v < row0 + ni:
                row_mask[g, v - row0] = 0.0
                want[g, v - row0, :] = 0.0
            col_mask[g, v] = 0.0
            want[g, :, v] = 0.0
        got = np.asarray(stages.a_mask(
            jnp.asarray(a), jnp.asarray(row_mask), jnp.asarray(col_mask)))
        assert (got.view(np.uint32) == want.view(np.uint32)).all(), \
            f"a_mask diverges from host zeroing on shard {shard} (P={p})"


@pytest.mark.parametrize("p", [1, 2, 3, 4, 6])
def test_sparse_forward_matches_monolithic(p):
    # The CSR path (edge tiles + degree vector, DESIGN.md §7) must compose
    # to the same scores as the monolithic dense model. p=4 gives NI=6 < the
    # chunk (12), covering the padded-source-chunk boundary.
    params, a, s, c, _, _ = _setup(b=3, n=24, seed=21)
    mono = model.full_forward(params, a, s, c)
    sp = dist_sim.dist_forward_sparse(params, a, s, c, p)
    assert_allclose(np.asarray(sp), np.asarray(mono), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("p", [1, 2, 3])
def test_sparse_grad_matches_jax_grad(p):
    params, a, s, c, onehot, targets = _setup(b=4, n=24, seed=23)
    want = model.full_loss_grad(params, a, s, c, onehot, targets)
    loss, got = dist_sim.dist_loss_and_grad_sparse(params, a, s, c, onehot, targets, p)
    want_loss = model.full_loss(params, a, s, c, onehot, targets)
    assert abs(float(loss) - float(want_loss)) < 1e-5
    for name in model.PARAM_ORDER:
        assert_allclose(np.asarray(got[name]), np.asarray(want[name]),
                        rtol=1e-4, atol=1e-5, err_msg=name)


def test_embed_pre_sp_matches_dense():
    # Degree-vector stage 1 vs the dense stage that row-sums A on device:
    # 0/1 row sums are small integers (exact in f32), so the two must agree
    # bit-for-bit.
    params, a, s, _, _, _ = _setup(b=2, n=24, seed=25)
    deg = jnp.sum(a, axis=2)
    dense = np.asarray(stages.embed_pre(
        params["theta1"], params["theta2"], params["theta3"], s, a))
    sp = np.asarray(stages.embed_pre_sp(
        params["theta1"], params["theta2"], params["theta3"], s, deg))
    assert (sp.view(np.uint32) == dense.view(np.uint32)).all(), \
        "embed_pre_sp diverges from the dense stage"


@pytest.mark.parametrize("caps", [(96, 768), (4, 8)])
def test_sparse_msg_matches_dense_bmm(caps):
    # Tiled gather/segment-sum vs the dense embed @ A — including tiny edge
    # capacities that force tile chaining within one (sc, dc) bucket.
    params, a, s, c, _, _ = _setup(b=2, n=24, seed=27)
    a_i = dist_sim.shard(a, 2, axis=1)[0]            # [B,12,24]
    e = jax.random.normal(jax.random.PRNGKey(2), (2, model.K, 12))
    want = stages.embed_msg(e, a_i, use_pallas=False)
    tiles = dist_sim.build_tiles(a_i, 12, caps)
    got = dist_sim.sparse_msg(e, tiles, 24, 12)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_sparse_msg_bwd_is_vjp_of_dense():
    params, a, _, _, _, _ = _setup(b=2, n=24, seed=29)
    a_i = dist_sim.shard(a, 2, axis=1)[1]
    e = jax.random.normal(jax.random.PRNGKey(3), (2, model.K, 12))
    d_partial = jax.random.normal(jax.random.PRNGKey(4), (2, model.K, 24))
    want = stages.embed_msg_bwd(a_i, d_partial)      # d @ A^T (dense VJP)
    tiles = dist_sim.build_tiles(a_i, 12, (96, 768))
    got = dist_sim.sparse_msg_bwd(d_partial, tiles, 12, 12)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_live_edge_mask_matches_dense_row_col_zeroing():
    # Node removal on the sparse path zeroes the live-edge mask w for every
    # edge incident to the removed node; the resulting messages must match
    # the dense path's row+column zeroing (Fig. 4).
    params, a, _, _, _, _ = _setup(b=2, n=24, seed=31)
    a = np.asarray(a).copy()
    e = jax.random.normal(jax.random.PRNGKey(5), (2, model.K, 24))
    removed = [(0, 5), (0, 13), (1, 2)]              # (batch element, node)
    tiles = dist_sim.build_tiles(jnp.asarray(a), 12, (96, 768))
    # Sparse removal: kill w where either endpoint is the removed node.
    # (P=1 here, so local row index == global node id.)
    masked = []
    for sc, dc, src, dst, w in tiles:
        w = w.copy()
        for g, v in removed:
            for pos in range(len(src)):
                if w[g, pos] == 0.0:
                    continue
                gsrc = sc * 12 + int(src[pos])
                gdst = dc * 12 + int(dst[pos])
                if gsrc == v or gdst == v:
                    w[g, pos] = 0.0
        masked.append((sc, dc, src, dst, w))
    got = dist_sim.sparse_msg(e, masked, 24, 12)
    # Dense removal: zero row + column.
    for g, v in removed:
        a[g, v, :] = 0.0
        a[g, :, v] = 0.0
    want = stages.embed_msg(e, jnp.asarray(a), use_pallas=False)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_q_sa_masking_selects_action_column():
    params, a, s, c, onehot, targets = _setup(b=4, n=24, seed=5)
    scores = model.full_forward(params, a, s, c)
    q_sa = jnp.sum(scores * onehot, axis=1)
    idx = jnp.argmax(onehot, axis=1)
    manual = jnp.take_along_axis(scores, idx[:, None], axis=1)[:, 0]
    assert_allclose(np.asarray(q_sa), np.asarray(manual), rtol=1e-6, atol=1e-6)


def test_gradients_are_nonzero():
    params, a, s, c, onehot, targets = _setup(b=4, n=24, seed=13)
    g = model.full_loss_grad(params, a, s, c, onehot, targets)
    for name in model.PARAM_ORDER:
        assert float(jnp.abs(g[name]).max()) > 0.0, f"{name} grad is zero"


def test_flat_roundtrip():
    params = model.init_params(jax.random.PRNGKey(0))
    flat = model.params_to_flat(params)
    assert flat.shape == (4 * model.K**2 + 4 * model.K,)
    back = model.flat_to_params(flat)
    for name in model.PARAM_ORDER:
        assert_allclose(np.asarray(back[name]), np.asarray(params[name]))
