"""AOT build-step correctness: manifests, shapes, binio interchange."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, binio, configs, model, stages


def test_bucket_invariants():
    for s in configs.fwd_shapes() + configs.train_shapes():
        assert s.n % 12 == 0
        assert s.n % s.ni == 0
        assert s.p in configs.P_SET


def test_artifact_names_unique_and_parse():
    arts = configs.all_artifacts()
    names = [n for n, _, _ in arts]
    assert len(names) == len(set(names))
    known = (configs.FWD_STAGES + configs.BWD_STAGES
             + configs.SPARSE_FWD_STAGES + configs.SPARSE_BWD_STAGES)
    for name, stage, s in arts:
        assert name == configs.artifact_name(stage, s)
        assert stage in known


def test_sparse_shape_slots_are_consistent():
    # Sparse stages overload the StageShape slots (n=EC, ni=NC for msg;
    # n=0 for the N-free pre stage); the rust manifest helpers rely on
    # these invariants (rust/src/runtime/manifest.rs).
    for s in configs.sparse_msg_shapes():
        assert s.ni in configs.SPARSE_CHUNKS        # NC
        assert s.n in configs.SPARSE_EDGE_CAPS      # EC
        assert s.n % s.ni == 0, "caps must be multiples of every chunk"
    for s in configs.sparse_fwd_shapes():
        # Every sparse bucket's shared stages and chunk must be compiled.
        nc = configs.chunk_for(s.ni)
        assert nc in configs.SPARSE_CHUNKS
        arts = {n for n, _, _ in configs.all_artifacts()}
        assert configs.artifact_name("q_scores", s) in arts
        sp = configs.StageShape(s.b, 0, s.ni)
        assert configs.artifact_name("embed_pre_sp", sp) in arts


def test_sparse_train_shapes_have_bwd_artifacts():
    arts = {n for n, _, _ in configs.all_artifacts()}
    for s in configs.sparse_train_shapes():
        for st in configs.SPARSE_SHARED_BWD:
            assert configs.artifact_name(st, s) in arts
        sp = configs.StageShape(s.b, 0, s.ni)
        assert configs.artifact_name("embed_pre_sp_bwd", sp) in arts
        nc = configs.chunk_for(s.ni)
        for ec in configs.SPARSE_EDGE_CAPS:
            assert configs.artifact_name(
                "embed_msg_sp_bwd", configs.StageShape(s.b, ec, nc)) in arts


def test_train_shapes_have_bwd_artifacts():
    arts = {n for n, _, _ in configs.all_artifacts()}
    for s in configs.train_shapes():
        for st in configs.BWD_STAGES:
            assert configs.artifact_name(st, s) in arts


def test_binio_roundtrip(tmp_path):
    p = tmp_path / "x.oggm"
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    b = np.asarray([1.5], dtype=np.float32)
    binio.save(p, [("a", a), ("b", b)])
    back = binio.load(p)
    assert_allclose(back["a"], a)
    assert_allclose(back["b"], b)
    assert back["a"].shape == (3, 4)


def test_example_args_match_stage_fns():
    # Every stage must lower against its declared example args.
    s = configs.StageShape(2, 24, 12)
    for stage in configs.FWD_STAGES + configs.BWD_STAGES:
        args = stages.example_args(stage, s.b, s.n, s.ni, configs.K)
        fn = stages.stage_fn(stage, use_pallas=False)
        lowered = jax.jit(fn).lower(*args)
        assert lowered is not None
    # Sparse stages lower at their overloaded slots (n=EC, ni=NC / n=0).
    for stage, (n, ni) in (("embed_pre_sp", (0, 12)), ("embed_msg_sp", (96, 12)),
                           ("embed_pre_sp_bwd", (0, 12)), ("embed_msg_sp_bwd", (96, 12))):
        args = stages.example_args(stage, s.b, n, ni, configs.K)
        fn = stages.stage_fn(stage, use_pallas=False)
        assert jax.jit(fn).lower(*args) is not None


def test_hlo_text_has_no_custom_calls():
    # interpret=True Pallas must lower to plain HLO (CPU-PJRT runnable).
    for stage in ("embed_msg", "embed_combine"):
        txt = aot.lower_stage(stage, configs.StageShape(1, 24, 12))
        assert "custom-call" not in txt.lower(), f"{stage} left a custom call"
        assert "ENTRY" in txt
    # The sparse gather/segment-sum must lower to a plain HLO scatter.
    txt = aot.lower_stage("embed_msg_sp", configs.StageShape(1, 96, 12))
    assert "custom-call" not in txt.lower()
    assert "scatter" in txt.lower()


def test_goldens_selfconsistent(tmp_path):
    aot.emit_goldens(str(tmp_path))
    g = binio.load(tmp_path / "golden_train.oggm")
    params = model.flat_to_params(jnp.asarray(g["params"]))
    scores = model.full_forward(params, g["a"], g["s"], g["c"])
    assert_allclose(np.asarray(scores), g["scores"], rtol=1e-5, atol=1e-5)
    loss = model.full_loss(params, g["a"], g["s"], g["c"], g["onehot"], g["targets"])
    assert abs(float(loss) - float(g["loss"][0])) < 1e-5
    gi = binio.load(tmp_path / "golden_infer.oggm")
    s1 = model.full_forward(params, gi["a"], gi["s"], gi["c"])
    assert_allclose(np.asarray(s1), gi["scores"], rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(not os.path.exists(os.path.join(os.path.dirname(__file__),
                    "..", "..", "artifacts", "manifest.tsv")),
                    reason="artifacts not built")
def test_manifest_covers_all_artifacts():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    rows = []
    with open(os.path.join(root, "manifest.tsv")) as f:
        for line in f:
            if line.startswith("#"):
                continue
            rows.append(line.rstrip("\n").split("\t"))
    assert len(rows) == len(configs.all_artifacts())
    for name, stage, b, n, ni, k, nout, fname in rows:
        assert os.path.exists(os.path.join(root, fname)), fname
        assert int(nout) == stages.STAGE_NUM_OUTPUTS[stage]
        assert int(n) % int(ni) == 0
