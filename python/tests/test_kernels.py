"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core kernel-correctness signal (the guides' contract for interpret-mode
Pallas on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import bmm as bmm_mod
from compile.kernels import fused as fused_mod
from compile.kernels import ref

DIMS = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48])
SMALL = st.sampled_from([1, 2, 3])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype=dtype)


@settings(max_examples=40, deadline=None)
@given(b=SMALL, k=DIMS, m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**16))
def test_bmm_matches_ref(b, k, m, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, k, m), dtype)
    y = _rand(rng, (b, m, n), dtype)
    got = bmm_mod.bmm(x, y)
    want = ref.bmm_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert got.dtype == want.dtype
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(b=SMALL, k=DIMS, ni=DIMS, dtype=DTYPES, seed=st.integers(0, 2**16))
def test_combine_matches_ref(b, k, ni, dtype, seed):
    rng = np.random.default_rng(seed)
    t4 = _rand(rng, (k, k), dtype)
    pre = _rand(rng, (b, k, ni), dtype)
    nbr = _rand(rng, (b, k, ni), dtype)
    got = fused_mod.combine(t4, pre, nbr)
    want = ref.combine_ref(t4, pre, nbr)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=tol, atol=tol)


@pytest.mark.parametrize("n,bn", [(24, 128), (128, 128), (252, 128), (96, 7)])
def test_bmm_block_picker(n, bn):
    picked = bmm_mod._pick_bn(n, bn)
    assert n % picked == 0 and 0 < picked <= max(bn, 1) or picked == n


def test_bmm_rejects_mismatch():
    x = jnp.zeros((1, 4, 5))
    y = jnp.zeros((1, 6, 7))
    with pytest.raises(AssertionError):
        bmm_mod.bmm(x, y)


@pytest.mark.parametrize("bn", [1, 2, 8, 64, 999])
def test_bmm_block_sweep_same_result(bn):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 24)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(2, 24, 48)).astype(np.float32))
    got = bmm_mod.bmm(x, y, bn=bn)
    assert_allclose(np.asarray(got), np.asarray(ref.bmm_ref(x, y)), rtol=1e-5, atol=1e-5)


def test_bmm_zero_and_identity():
    # x @ I == x ; x @ 0 == 0 — degenerate structure the masking path relies on.
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 32, 12)).astype(np.float32))
    eye = jnp.broadcast_to(jnp.eye(12, dtype=jnp.float32), (1, 12, 12))
    assert_allclose(np.asarray(bmm_mod.bmm(x, eye)), np.asarray(x), rtol=1e-6, atol=1e-6)
    zero = jnp.zeros((1, 12, 20), jnp.float32)
    assert np.abs(np.asarray(bmm_mod.bmm(x, zero))).max() == 0.0


def test_combine_relu_clamps():
    # With pre = -inf-ish negative and nbr = 0, output must be exactly 0.
    t4 = jnp.zeros((4, 4), jnp.float32)
    pre = -jnp.ones((1, 4, 8), jnp.float32)
    nbr = jnp.zeros((1, 4, 8), jnp.float32)
    out = fused_mod.combine(t4, pre, nbr)
    assert np.asarray(out).max() == 0.0
