"""Python writer/reader for the "OGGM" binary tensor container.

Mirrors rust/src/util/binio.rs exactly (little-endian, f32 payloads). Used
to ship golden test vectors and initial parameters from the build step to
the Rust integration tests.
"""

import struct

MAGIC = b"OGGM"
VERSION = 1


def save(path, tensors):
    """tensors: list of (name, numpy f32 array)."""
    import numpy as np

    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load(path):
    """Returns dict name -> numpy f32 array."""
    import numpy as np

    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            n = 1
            for d in dims:
                n *= d
            arr = np.frombuffer(f.read(4 * n), dtype=np.float32).reshape(dims)
            out[name] = arr
    return out
