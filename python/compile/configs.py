"""Shape-bucket registry: the single source of truth for which (stage, B, N,
NI) combinations are AOT-lowered to HLO artifacts.

Graphs are padded with isolated nodes to the next bucket size divisible by
lcm{1,2,3,4,6} = 12 so every device count P in {1,2,3,4,6} yields an integer
shard height NI = N / P. The Rust coordinator reads artifacts/manifest.tsv
(written by aot.py) and refuses shapes that were not compiled.

K (embedding dim) is fixed at 32 per the paper's hyper-parameters; L (number
of embedding layers, 2 in the paper) is a *runtime* loop in the coordinator
and never enters artifact shapes because stages are per-layer.
"""

from dataclasses import dataclass

K = 32          # graph-embedding dimension (paper Sec. 6.1)
L = 2           # embedding layers (runtime loop, recorded for reference)
P_SET = (1, 2, 3, 4, 6)   # device counts exercised (one Summit node = 6 GPUs)

# a_mask is the device-side residual-graph patch for the device-resident
# coordinator path (Rust DeviceState): emitted alongside every fwd shape so
# any solvable shape can also be patched in place.
FWD_STAGES = ("embed_pre", "embed_msg", "embed_combine", "q_sum", "q_scores", "a_mask")
BWD_STAGES = ("embed_pre_bwd", "embed_msg_bwd", "embed_combine_bwd", "q_scores_bwd")

# Sparse (CSR) compute path (DESIGN.md §7). Only two stage families touch
# the adjacency, so only they get sparse replacements:
#   embed_pre_sp  — per (B, NI): degree-vector variant of embed_pre (N-free;
#                   emitted with n=0 in its name/manifest row).
#   embed_msg_sp  — per (B, NC, EC): gather + segment-sum over one padded
#                   edge tile (named/manifested with n=EC, ni=NC).
# combine / q_sum / q_scores (and their bwd) are already N-free in math, so
# the sparse path reuses the dense-named artifacts at (B, N, NI) — sparse
# buckets emit those names below without the dense embed_pre/embed_msg/
# a_mask, which is exactly where the O(NI·N) artifact surface disappears.
SPARSE_FWD_STAGES = ("embed_pre_sp", "embed_msg_sp")
SPARSE_BWD_STAGES = ("embed_pre_sp_bwd", "embed_msg_sp_bwd")
SPARSE_SHARED_FWD = ("embed_combine", "q_sum", "q_scores")
SPARSE_SHARED_BWD = ("embed_combine_bwd", "q_scores_bwd")

# Node-chunk / edge-capacity ladders shared by every sparse bucket. The
# coordinator picks the largest chunk <= NI (else the smallest available;
# rust/src/runtime/manifest.rs `sparse_chunk_for` mirrors chunk_for below)
# and per tile the smallest capacity that fits, chaining overflow into
# sibling tiles. Capacities are multiples of every chunk so the shapes
# satisfy StageShape's divisibility checks when carried in its (n, ni)
# slots.
SPARSE_CHUNKS = (12, 48)
SPARSE_EDGE_CAPS = (96, 768)

# Small/medium (bucket, device-set) pairs shared by fwd_shapes() and
# batch_shapes(): the learning-curve buckets (Fig. 6/8) where graph-level
# batching is the utilization lever. Keeping one list prevents the B=1 and
# B>1 artifact sets from drifting apart.
BATCHED_BUCKETS = ((24, P_SET), (252, (1, 2, 3)))


@dataclass(frozen=True, order=True)
class StageShape:
    """One artifact shape: minibatch B, padded node count N, shard height NI."""

    b: int
    n: int
    ni: int

    def __post_init__(self):
        assert self.n % 12 == 0, f"bucket N={self.n} must be divisible by 12"
        assert self.n % self.ni == 0, f"NI={self.ni} must divide N={self.n}"

    @property
    def p(self) -> int:
        return self.n // self.ni


def _shards(n: int, ps) -> list:
    return [StageShape(1, n, n // p) for p in ps]


def fwd_shapes() -> list:
    """Inference / policy-evaluation shapes (B = 1)."""
    shapes = []
    # Learning-curve graphs (Fig. 6/8): train |V|=20 -> 24, test |V|=250 -> 252.
    for n, ps in BATCHED_BUCKETS:
        shapes += _shards(n, ps)
    # Multi-node-selection study (Fig. 7): 750/1500/3000-node graphs, P = 1.
    shapes += _shards(756, (1,))
    shapes += _shards(1500, (1,))
    shapes += _shards(3000, (1,))
    # ER scaling study (Fig. 9/11): paper used 15000/21000; quarter-scaled
    # per DESIGN.md Sec. 3 while keeping rho = 0.15.
    shapes += _shards(1488, P_SET)
    shapes += _shards(2496, P_SET)
    # Social-graph scaling study (Fig. 10 / Table 1): Holme-Kim stand-ins.
    shapes += _shards(2028, P_SET)
    shapes += _shards(2352, P_SET)
    shapes += _shards(2628, P_SET)
    return shapes


def batch_shapes() -> list:
    """Graph-level batched inference shapes (fwd stages only).

    The Rust batch engine (rust/src/batch/) packs B graphs block-diagonally
    and steps them through one shared forward pass; eviction/compaction
    drops finished graphs to the next smaller compiled capacity, so each
    BATCHED_BUCKETS entry gets a capacity ladder B in {2, 4, 8} on top of
    its B=1 shapes from fwd_shapes(). Small/medium buckets only —
    graph-level batching is the small-graph utilization lever (large
    graphs already fill devices).
    """
    shapes = []
    for b in (2, 4, 8):
        for n, ps in BATCHED_BUCKETS:
            shapes += [StageShape(b, n, n // p) for p in ps]
    return shapes


def train_shapes() -> list:
    """Training minibatch shapes (fwd AND bwd stages are emitted)."""
    shapes = []
    # Learning curves train on 20-node graphs with minibatch 8; the small
    # P>1 variants exist for the Rust distributed-gradient parity tests.
    shapes += [StageShape(8, 24, ni) for ni in (24, 12, 8)]
    shapes += [StageShape(8, 252, 252)]
    # Fig. 11 training-scaling study (B = 4 keeps the dense minibatch
    # within memory at these sizes; see DESIGN.md Sec. 2).
    shapes += [StageShape(4, 1488, 1488 // p) for p in P_SET]
    shapes += [StageShape(4, 2496, 2496 // p) for p in P_SET]
    return shapes


def chunk_for(ni: int) -> int:
    """Node chunk NC used at shard height NI: the largest compiled chunk
    that fits, else the smallest (chunks need not divide NI — the
    coordinator zero-pads the last source chunk and clips the last
    destination chunk)."""
    fits = [c for c in SPARSE_CHUNKS if c <= ni]
    return max(fits) if fits else min(SPARSE_CHUNKS)


def sparse_fwd_shapes() -> list:
    """Buckets served by the sparse CSR inference path.

    The small/medium buckets double up with the dense set (the dense path
    stays the golden oracle there — rust/tests/sparse_equivalence.rs), with
    the full batch-capacity ladder so the batched engine can repack packs
    on the sparse path too. The large buckets are sparse-ONLY: no dense
    embed_pre/embed_msg/a_mask is compiled for them, so their artifact and
    runtime footprint scales with E and NI, never NI·N (DESIGN.md §7).
    """
    shapes = []
    for b in (1, 2, 4, 8):
        for n, ps in BATCHED_BUCKETS:
            shapes += [StageShape(b, n, n // p) for p in ps]
    # Sparse-only scaling buckets (§7 ladder): ~5k and ~10k nodes at every
    # device count; 12 | N and P | N for P in {1,2,3,4,6}.
    shapes += _shards(4992, P_SET)
    shapes += _shards(9996, P_SET)
    return shapes


def sparse_train_shapes() -> list:
    """Training minibatch shapes compiled for the sparse path (fwd + bwd
    sparse stages; parity with the dense train_shapes() small bucket)."""
    return [StageShape(8, 24, ni) for ni in (24, 12, 8)]


def sparse_msg_shapes(train_only: bool = False) -> list:
    """(B, NC, EC) combinations for embed_msg_sp, carried as
    StageShape(b, n=EC, ni=NC). One entry per (batch size, chunk) in use,
    at every edge capacity of the ladder."""
    src = sparse_train_shapes() if train_only else sparse_fwd_shapes()
    combos = sorted({(s.b, chunk_for(s.ni)) for s in src})
    return [
        StageShape(b, ec, nc)
        for (b, nc) in combos
        for ec in SPARSE_EDGE_CAPS
    ]


def artifact_name(stage: str, s: StageShape) -> str:
    return f"{stage}_b{s.b}_n{s.n}_ni{s.ni}_k{K}"


def all_artifacts() -> list:
    """[(name, stage, shape)] for every artifact to emit (deduplicated)."""
    out = {}
    for s in fwd_shapes() + batch_shapes():
        for st in FWD_STAGES:
            out[artifact_name(st, s)] = (st, s)
    for s in train_shapes():
        for st in FWD_STAGES + BWD_STAGES:
            out[artifact_name(st, s)] = (st, s)
    # Sparse path (DESIGN.md §7): N-free stages + shared dense-named ones.
    for s in sparse_fwd_shapes():
        for st in SPARSE_SHARED_FWD:
            out[artifact_name(st, s)] = (st, s)
        sp = StageShape(s.b, 0, s.ni)
        out[artifact_name("embed_pre_sp", sp)] = ("embed_pre_sp", sp)
    for s in sparse_msg_shapes():
        out[artifact_name("embed_msg_sp", s)] = ("embed_msg_sp", s)
    for s in sparse_train_shapes():
        for st in SPARSE_SHARED_BWD:
            out[artifact_name(st, s)] = (st, s)
        sp = StageShape(s.b, 0, s.ni)
        out[artifact_name("embed_pre_sp_bwd", sp)] = ("embed_pre_sp_bwd", sp)
    for s in sparse_msg_shapes(train_only=True):
        out[artifact_name("embed_msg_sp_bwd", s)] = ("embed_msg_sp_bwd", s)
    return [(name, st, s) for name, (st, s) in sorted(out.items())]


# Buckets for which the pallas kernels are used in the emitted artifact.
# Very large buckets fall back to the mathematically-identical jnp path to
# keep interpret-mode grid loops off the measured hot path (DESIGN.md §2);
# kernel correctness at all sizes is covered by pytest instead.
PALLAS_MAX_N = 1600


def use_pallas(s: StageShape) -> bool:
    return s.n <= PALLAS_MAX_N
