"""AOT build step: lower every (stage x shape bucket) to HLO text, write the
artifact manifest, golden test vectors, and initial parameters.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Run via `make artifacts`:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import binio, configs, model, stages


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(stage: str, shape: configs.StageShape) -> str:
    fn = stages.stage_fn(stage, use_pallas=configs.use_pallas(shape))
    args = stages.example_args(stage, shape.b, shape.n, shape.ni, configs.K)
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    return to_hlo_text(lowered)


def emit_artifacts(outdir: str, only: str | None = None) -> int:
    arts = configs.all_artifacts()
    if only:
        arts = [(n, st, s) for (n, st, s) in arts if only in n]
    manifest_rows = []
    emitted = 0
    for i, (name, stage, shape) in enumerate(arts):
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        manifest_rows.append(
            (name, stage, shape.b, shape.n, shape.ni, configs.K,
             stages.STAGE_NUM_OUTPUTS[stage], fname)
        )
        if os.path.exists(path):
            continue
        text = lower_stage(stage, shape)
        with open(path + ".tmp", "w") as f:
            f.write(text)
        os.replace(path + ".tmp", path)
        emitted += 1
        if (i + 1) % 25 == 0 or i + 1 == len(arts):
            print(f"  [{i+1}/{len(arts)}] {name}", flush=True)
    # Manifest written last: its presence marks a complete artifact set.
    with open(os.path.join(outdir, "manifest.tsv"), "w") as f:
        f.write(f"# oggm artifact manifest\tk={configs.K}\tl={configs.L}\n")
        f.write("# name\tstage\tb\tn\tni\tk\tnum_outputs\tfile\n")
        for row in manifest_rows:
            f.write("\t".join(str(x) for x in row) + "\n")
    return emitted


# ------------------------------------------------------------------ goldens

def _random_instance(key, b, n, rho=0.15):
    """Random padded MVC state: adjacency (symmetric, zero diag), S, C."""
    k1, k2, k3 = jax.random.split(key, 3)
    upper = (jax.random.uniform(k1, (b, n, n)) < rho).astype(jnp.float32)
    upper = jnp.triu(upper, k=1)
    a = upper + jnp.transpose(upper, (0, 2, 1))
    s = (jax.random.uniform(k2, (b, n)) < 0.2).astype(jnp.float32)
    # Candidates: not in partial solution.
    c = 1.0 - s
    del k3
    return a, s, c


def emit_goldens(outdir: str) -> None:
    """Golden vectors for the Rust distributed fwd/bwd parity tests."""
    key = jax.random.PRNGKey(20210661)
    pkey, gkey, akey, tkey, fkey = jax.random.split(key, 5)
    params = model.init_params(pkey)
    flat = np.asarray(model.params_to_flat(params), dtype=np.float32)

    # --- training golden: B=8, N=24 (matches train artifacts, P in {1,2,3})
    b, n = 8, 24
    a, s, c = _random_instance(gkey, b, n)
    onehot_idx = jax.random.randint(akey, (b,), 0, n)
    onehot = jax.nn.one_hot(onehot_idx, n, dtype=jnp.float32)
    # Actions must be valid candidates for realism (not required by math).
    c = jnp.maximum(c, onehot)
    targets = jax.random.normal(tkey, (b,))
    scores = model.full_forward(params, a, s, c)
    loss = model.full_loss(params, a, s, c, onehot, targets)
    grads = model.full_loss_grad(params, a, s, c, onehot, targets)
    gflat = np.asarray(model.params_to_flat(grads), dtype=np.float32)
    binio.save(
        os.path.join(outdir, "golden_train.oggm"),
        [
            ("params", flat),
            ("a", np.asarray(a)),
            ("s", np.asarray(s)),
            ("c", np.asarray(c)),
            ("onehot", np.asarray(onehot)),
            ("targets", np.asarray(targets)),
            ("scores", np.asarray(scores)),
            ("loss", np.asarray([loss])),
            ("grads", gflat),
        ],
    )

    # --- inference golden: B=1, N=24 (matches fwd artifacts, P in P_SET)
    a1, s1, c1 = _random_instance(fkey, 1, 24)
    scores1 = model.full_forward(params, a1, s1, c1)
    binio.save(
        os.path.join(outdir, "golden_infer.oggm"),
        [
            ("params", flat),
            ("a", np.asarray(a1)),
            ("s", np.asarray(s1)),
            ("c", np.asarray(c1)),
            ("scores", np.asarray(scores1)),
        ],
    )

    # Initial parameters for reproducible Rust training runs.
    binio.save(os.path.join(outdir, "params_init.oggm"), [("params", flat)])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    n = emit_artifacts(args.out, args.only)
    emit_goldens(args.out)
    print(f"aot: emitted {n} new HLO artifacts + goldens to {args.out}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
