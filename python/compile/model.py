"""Monolithic single-device reference policy model (the parity oracle).

This is Alg. 2 + Alg. 3 with P = 1 written straight down, plus the DQN loss.
The distributed stage composition (python simulation in tests, and the Rust
coordinator against golden vectors) must match `full_forward` and
`jax.grad(full_loss)` to fp tolerance; that is the core correctness signal
for the hand-rolled distributed backprop.
"""

import jax
import jax.numpy as jnp

from .kernels import ref

K = 32
L = 2


def init_params(key, k: int = K):
    """Parameter pytree theta1..theta7 (Eq. 1 and Eq. 2)."""
    ks = jax.random.split(key, 7)
    scale = 0.1
    return {
        "theta1": scale * jax.random.normal(ks[0], (k,)),
        "theta2": scale * jax.random.normal(ks[1], (k,)),
        "theta3": scale * jax.random.normal(ks[2], (k, k)),
        "theta4": scale * jax.random.normal(ks[3], (k, k)),
        "theta5": scale * jax.random.normal(ks[4], (k, k)),
        "theta6": scale * jax.random.normal(ks[5], (k, k)),
        "theta7": scale * jax.random.normal(ks[6], (2 * k,)),
    }


PARAM_ORDER = ("theta1", "theta2", "theta3", "theta4", "theta5", "theta6", "theta7")


def params_to_flat(params):
    """Flatten in the layout rust/src/model/params.rs expects."""
    return jnp.concatenate([params[name].reshape(-1) for name in PARAM_ORDER])


def flat_to_params(flat, k: int = K):
    shapes = {
        "theta1": (k,), "theta2": (k,), "theta3": (k, k), "theta4": (k, k),
        "theta5": (k, k), "theta6": (k, k), "theta7": (2 * k,),
    }
    out, off = {}, 0
    for name in PARAM_ORDER:
        sz = 1
        for d in shapes[name]:
            sz *= d
        out[name] = flat[off:off + sz].reshape(shapes[name])
        off += sz
    assert off == flat.shape[0]
    return out


def full_forward(params, a, s, c, layers: int = L):
    """Scores for every node: a [B,N,N], s [B,N], c [B,N] -> [B,N]."""
    pre = ref.embed_pre_ref(params["theta1"], params["theta2"], params["theta3"], s, a)
    embed = jnp.zeros_like(pre)  # Alg. 2 line 3
    for _ in range(layers):
        nbr = ref.bmm_ref(embed, a)  # single shard: partial == total
        embed = ref.combine_ref(params["theta4"], pre, nbr)
    sum_all = jnp.sum(embed, axis=2)
    return ref.q_scores_ref(
        params["theta5"], params["theta6"], params["theta7"], embed, c, sum_all
    )


def full_loss(params, a, s, c, action_onehot, targets, layers: int = L):
    """DQN regression loss: mean_b (Q(s_b, a_b) - y_b)^2."""
    scores = full_forward(params, a, s, c, layers)
    q_sa = jnp.sum(scores * action_onehot, axis=1)
    return jnp.mean((q_sa - targets) ** 2)


full_loss_grad = jax.grad(full_loss)
