"""Pure-jnp oracles for the Pallas kernels and the stage math.

These are the correctness reference for:
  * pytest kernel-vs-ref checks (hypothesis sweeps shapes/dtypes), and
  * the backward stages (VJPs are taken against this math; it is
    element-for-element identical to the kernels' outputs, see DESIGN.md).
"""

import jax
import jax.numpy as jnp


def bmm_ref(x, y):
    """Batched matmul: x [B,K,M] @ y [B,M,N] -> [B,K,N].

    This is Alg. 2 line 11 (`nbr_embed^i = SpMatMul(embed^i, A^i)`), the
    message-passing hot spot, densified (see DESIGN.md Sec. 3).
    """
    return jnp.einsum("bkm,bmn->bkn", x, y)


def combine_ref(theta4, pre, nbr):
    """Layer combine: relu(pre + theta4 @ nbr)  (Alg. 2 lines 13-14).

    theta4 [K,K]; pre, nbr [B,K,NI].
    """
    return jax.nn.relu(pre + jnp.einsum("km,bmj->bkj", theta4, nbr))


# --- full stage math (used by stages.py's ref path and by the VJPs) ---


def embed_pre_deg_ref(theta1, theta2, theta3, s, deg):
    """`embed_pre` math with the residual degree vector as a direct input.

    theta1, theta2 [K]; theta3 [K,K]; s, deg [B,NI] -> pre [B,K,NI].
    The dense stage derives deg = sum(A, axis=2) on device; the sparse
    (CSR) path maintains deg host-side from the live-edge counts and never
    materializes A — the two are bit-identical because the 0/1 row sums are
    small integers, exactly representable in f32.
    """
    e1 = theta1[None, :, None] * s[:, None, :]
    w = jax.nn.relu(theta2[None, :, None] * deg[:, None, :])
    e2 = jnp.einsum("km,bmj->bkj", theta3, w)
    return e1 + e2


def embed_pre_ref(theta1, theta2, theta3, s, a):
    """Alg. 2 lines 5-8: the layer-independent part of the embedding.

    theta1, theta2 [K]; theta3 [K,K]; s [B,NI]; a [B,NI,N] -> pre [B,K,NI].
    e1 = theta1 (x) S^T; w = relu(theta2 (x) deg); e2 = theta3 @ w.
    """
    return embed_pre_deg_ref(theta1, theta2, theta3, s, jnp.sum(a, axis=2))


def q_scores_ref(theta5, theta6, theta7, embed, c, sum_all):
    """Alg. 3 lines 6-11: candidate scores for the local shard.

    theta5, theta6 [K,K]; theta7 [2K]; embed [B,K,NI]; c [B,NI] (0/1 mask);
    sum_all [B,K] (the all-reduced global embedding sum) -> scores [B,NI].
    The paper's SPARSE_DIAG(C) extraction is the mask multiply `embed * c`.
    """
    w1 = jnp.einsum("km,bm->bk", theta5, sum_all)
    ce = embed * c[:, None, :]
    w2 = jnp.einsum("km,bmj->bkj", theta6, ce)
    b, k, ni = w2.shape
    h = jax.nn.relu(
        jnp.concatenate([jnp.broadcast_to(w1[:, :, None], (b, k, ni)), w2], axis=1)
    )
    return jnp.einsum("t,btj->bj", theta7, h)
