"""L1 Pallas kernel: blocked batched matmul for the message-passing hot spot.

Computes partial[b] = embed[b] @ A[b]  (K x NI) @ (NI x N), i.e. Alg. 2
line 11. The paper's CUDA implementation expressed the HBM<->SM schedule
with threadblocks over cuSPARSE SpMM tiles; on TPU the same insight becomes
a BlockSpec HBM->VMEM pipeline (DESIGN.md Sec. 6):

  * grid = (B, N / bn): one program instance per (graph, output column
    block). The full (K x NI) LHS block stays VMEM-resident across the
    grid's inner dimension (K = 32 keeps it small), while (NI x bn) RHS
    blocks stream through VMEM.
  * the inner contraction is a single MXU-shaped dot per instance; f32 is
    kept for CPU-interpret numerics (bf16 would be the on-TPU layout).

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred output-column block width. The grid dimension requires bn | N;
# `_pick_bn` degrades gracefully for the bucket sizes (all divisible by 12).
BN_DEFAULT = 128


def _pick_bn(n: int, bn: int) -> int:
    """Largest block width <= bn that divides n."""
    if n <= bn:
        return n
    for cand in range(min(bn, n), 0, -1):
        if n % cand == 0:
            return cand
    return n


def _bmm_kernel(x_ref, y_ref, o_ref):
    # x_ref: (1, K, M) LHS block; y_ref: (1, M, bn) RHS block; o: (1, K, bn).
    x = x_ref[0]
    y = y_ref[0]
    o_ref[0] = jnp.dot(x, y, preferred_element_type=o_ref.dtype)


@functools.partial(jax.named_call, name="pallas_bmm")
def bmm(x, y, *, bn: int = BN_DEFAULT):
    """Batched matmul x [B,K,M] @ y [B,M,N] -> [B,K,N] via Pallas.

    Matches kernels.ref.bmm_ref exactly (the pytest + hypothesis suite
    asserts allclose over shape/dtype sweeps).
    """
    b, k, m = x.shape
    b2, m2, n = y.shape
    assert b == b2 and m == m2, f"bmm shape mismatch {x.shape} @ {y.shape}"
    bn = _pick_bn(n, bn)
    grid = (b, n // bn)
    return pl.pallas_call(
        _bmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k, m), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, m, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, k, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, k, n), x.dtype),
        interpret=True,
    )(x, y)
