"""L1 Pallas kernel: fused embedding-layer combine.

Computes embed = relu(pre + theta4 @ nbr)  (Alg. 2 lines 13-14) in one VMEM
round trip instead of three HLO ops: the (K x K) weight is broadcast to every
grid instance, each instance owns one graph's (K x NI_block) activation tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bmm import _pick_bn

BJ_DEFAULT = 256  # NI-column block


def _combine_kernel(t4_ref, pre_ref, nbr_ref, o_ref):
    t4 = t4_ref[...]
    pre = pre_ref[0]
    nbr = nbr_ref[0]
    o_ref[0] = jax.nn.relu(pre + jnp.dot(t4, nbr, preferred_element_type=o_ref.dtype))


@functools.partial(jax.named_call, name="pallas_combine")
def combine(theta4, pre, nbr, *, bj: int = BJ_DEFAULT):
    """relu(pre + theta4 @ nbr): theta4 [K,K]; pre, nbr [B,K,NI]."""
    b, k, ni = pre.shape
    assert theta4.shape == (k, k) and nbr.shape == (b, k, ni)
    bj = _pick_bn(ni, bj)
    grid = (b, ni // bj)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, k), lambda i, j: (0, 0)),
            pl.BlockSpec((1, k, bj), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, k, bj), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, k, bj), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, k, ni), pre.dtype),
        interpret=True,
    )(theta4, pre, nbr)
