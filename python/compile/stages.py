"""L2 stage functions: the paper's policy model split at its communication
points (DESIGN.md Sec. 2 stage catalog).

Each forward stage is one per-shard HLO program; collectives between stages
(Alg. 2 line 12 all-reduce, Alg. 3 line 5 all-reduce, Alg. 4 line 6
all-gather) belong to the Rust coordinator. Backward stages are jax.vjp of
the ref math (identical element-for-element to the kernel outputs).

Argument orders here define the PJRT parameter orders the Rust runtime uses;
change them only together with rust/src/runtime/exec.rs.
"""

import jax
import jax.numpy as jnp

from .kernels import bmm as bmm_mod
from .kernels import fused as fused_mod
from .kernels import ref


# ---------------------------------------------------------------- forward

def embed_pre(theta1, theta2, theta3, s, a):
    """Stage 1 (Alg. 2 lines 5-8): layer-independent embedding terms."""
    return ref.embed_pre_ref(theta1, theta2, theta3, s, a)


def embed_msg(embed, a, *, use_pallas=True):
    """Stage 2 (Alg. 2 line 11): local message-passing partial sums.

    embed [B,K,NI] @ a [B,NI,N] -> partial [B,K,N]; the coordinator
    all-reduces the result across shards (Alg. 2 line 12).
    """
    if use_pallas:
        return bmm_mod.bmm(embed, a)
    return ref.bmm_ref(embed, a)


def embed_combine(theta4, pre, nbr, *, use_pallas=True):
    """Stage 3 (Alg. 2 lines 13-14): embed = relu(pre + theta4 @ nbr).

    `nbr` is this shard's column slice of the all-reduced message tensor
    (the coordinator slices before invoking).
    """
    if use_pallas:
        return fused_mod.combine(theta4, pre, nbr)
    return ref.combine_ref(theta4, pre, nbr)


def q_sum(embed):
    """Stage 4 (Alg. 3 line 4): local embedding sum, shape [B,K]."""
    return jnp.sum(embed, axis=2)


def q_scores(theta5, theta6, theta7, embed, c, sum_all):
    """Stage 5 (Alg. 3 lines 6-11): local candidate scores [B,NI]."""
    return ref.q_scores_ref(theta5, theta6, theta7, embed, c, sum_all)


def embed_pre_sp(theta1, theta2, theta3, s, deg):
    """Sparse stage 1: `embed_pre` with the degree vector as input.

    theta1, theta2 [K]; theta3 [K,K]; s, deg [B,NI] -> pre [B,K,NI].
    The CSR path never materializes the B*NI*N adjacency; the coordinator
    maintains the live out-degree per shard row (SparseShard::deg) and
    uploads it instead. deg entries are small integers, so this is
    bit-identical to the dense stage's on-device row sum.
    """
    return ref.embed_pre_deg_ref(theta1, theta2, theta3, s, deg)


def embed_msg_sp(embed_chunk, src, dst, w):
    """Sparse stage 2 over one (source-chunk, dest-chunk) edge tile.

    Gather-from-neighbor + segment-sum over a padded edge list (S2V-DQN's
    sparse message passing; Dai et al., Drori et al.):

      embed_chunk [B,K,NC]  — source-chunk slice of the local embedding
      src, dst    [EC]      — chunk-local endpoint indices as f32 (cast to
                              int32 in-stage; exact for indices < 2^24,
                              keeping the runtime's f32-only upload path)
      dst gathers nothing: out[b,k,c] = sum_e [dst_e == c] *
                              embed_chunk[b,k,src_e] * w[b,e]
      w           [B,EC]    — per-batch-element live-edge mask (0 for
                              padding, removed edges, and edges belonging
                              to other graphs of the pack)

    Returns the tile's partial message [B,K,NC] for the destination chunk.
    Artifact shapes depend on (B, NC, EC, K) only — never on N — which is
    what makes the compiled set reusable across all graph sizes
    (DESIGN.md §7).
    """
    si = src.astype(jnp.int32)
    di = dst.astype(jnp.int32)
    b, k, nc = embed_chunk.shape
    vals = embed_chunk[:, :, si] * w[:, None, :]
    return jnp.zeros((b, k, nc), embed_chunk.dtype).at[:, :, di].add(vals)


def embed_msg_sp_bwd(d_chunk, src, dst, w):
    """VJP of `embed_msg_sp` w.r.t. its embedding input (edges are data).

    d_chunk [B,K,NC] is the destination-chunk cotangent; the adjoint of a
    gather+segment-sum is the reversed gather+segment-sum:
      d_embed[b,k,j] = sum_e [src_e == j] * d_chunk[b,k,dst_e] * w[b,e].
    """
    si = src.astype(jnp.int32)
    di = dst.astype(jnp.int32)
    b, k, nc = d_chunk.shape
    vals = d_chunk[:, :, di] * w[:, None, :]
    return jnp.zeros((b, k, nc), d_chunk.dtype).at[:, :, si].add(vals)


def embed_pre_sp_bwd(theta1, theta2, theta3, s, deg, d_pre):
    """d(theta1, theta2, theta3) for sparse stage 1."""
    _, vjp = jax.vjp(
        lambda t1, t2, t3: ref.embed_pre_deg_ref(t1, t2, t3, s, deg),
        theta1, theta2, theta3,
    )
    return vjp(d_pre)


def a_mask(a, row_mask, col_mask):
    """Device-side residual-graph update for the device-resident path.

    a [B,NI,N] * row_mask [B,NI] (broadcast over columns) * col_mask [B,N]
    (broadcast over rows). Node removal (Fig. 4) only ever zeroes rows and
    columns, so multiplying by 0/1 masks reproduces the host-side update
    bit-exactly (1.0*x == x, 0.0*x == 0.0 for the 0/1 adjacency entries) —
    the coordinator uploads two small mask vectors per step instead of the
    full B*NI*N shard adjacency (rust/src/coordinator/fwd.rs DeviceState).
    """
    return a * row_mask[:, :, None] * col_mask[:, None, :]


# ---------------------------------------------------------------- backward
# VJP stages. Data inputs (s, a, c) never need cotangents; the collective
# adjoints (all-gather of d_nbr, all-reduce of d_sum_all / d_theta) and the
# trivial q_sum broadcast adjoint live in the Rust coordinator.

def embed_pre_bwd(theta1, theta2, theta3, s, a, d_pre):
    """d(theta1, theta2, theta3) for stage 1."""
    _, vjp = jax.vjp(lambda t1, t2, t3: ref.embed_pre_ref(t1, t2, t3, s, a),
                     theta1, theta2, theta3)
    return vjp(d_pre)


def embed_msg_bwd(a, d_partial):
    """d_embed for stage 2: d_partial [B,K,N] x a [B,NI,N] -> [B,K,NI].

    The cotangent of x @ A w.r.t. x is d @ A^T; `a` itself is data.
    """
    return jnp.einsum("bkn,bjn->bkj", d_partial, a)


def embed_combine_bwd(theta4, pre, nbr, d_out):
    """(d_theta4, d_pre, d_nbr) for stage 3."""
    _, vjp = jax.vjp(lambda t4, p, nb: ref.combine_ref(t4, p, nb), theta4, pre, nbr)
    return vjp(d_out)


def q_scores_bwd(theta5, theta6, theta7, embed, c, sum_all, d_scores):
    """(d_theta5, d_theta6, d_theta7, d_embed, d_sum_all) for stage 5."""
    _, vjp = jax.vjp(
        lambda t5, t6, t7, e, sa: ref.q_scores_ref(t5, t6, t7, e, c, sa),
        theta5, theta6, theta7, embed, sum_all,
    )
    return vjp(d_scores)


# ------------------------------------------------- stage registry for AOT

def example_args(stage: str, b: int, n: int, ni: int, k: int):
    """jax.ShapeDtypeStruct argument list for lowering `stage`.

    Sparse stages overload the (n, ni) slots (mirrored by the manifest
    columns, see rust/src/runtime/manifest.rs): for `embed_msg_sp*`,
    n = EC (edge capacity) and ni = NC (node chunk); for `embed_pre_sp*`,
    n = 0 (the stage is N-free) and ni keeps its meaning.
    """
    f32 = jnp.float32
    t_k = jax.ShapeDtypeStruct((k,), f32)
    t_kk = jax.ShapeDtypeStruct((k, k), f32)
    t_2k = jax.ShapeDtypeStruct((2 * k,), f32)
    s_bni = jax.ShapeDtypeStruct((b, ni), f32)
    a_bnin = jax.ShapeDtypeStruct((b, ni, n), f32)
    e_bkni = jax.ShapeDtypeStruct((b, k, ni), f32)
    m_bkn = jax.ShapeDtypeStruct((b, k, n), f32)
    v_bk = jax.ShapeDtypeStruct((b, k), f32)
    v_bn = jax.ShapeDtypeStruct((b, n), f32)
    sc_bni = jax.ShapeDtypeStruct((b, ni), f32)
    table = {
        "embed_pre": [t_k, t_k, t_kk, s_bni, a_bnin],
        "embed_msg": [e_bkni, a_bnin],
        "embed_combine": [t_kk, e_bkni, e_bkni],
        "q_sum": [e_bkni],
        "q_scores": [t_kk, t_kk, t_2k, e_bkni, s_bni, v_bk],
        "a_mask": [a_bnin, s_bni, v_bn],
        "embed_pre_sp": [t_k, t_k, t_kk, s_bni, s_bni],
        "embed_msg_sp": [
            jax.ShapeDtypeStruct((b, k, ni), f32),  # embed_chunk [B,K,NC]
            jax.ShapeDtypeStruct((n,), f32),        # src [EC]
            jax.ShapeDtypeStruct((n,), f32),        # dst [EC]
            jax.ShapeDtypeStruct((b, n), f32),      # w [B,EC]
        ],
        "embed_pre_bwd": [t_k, t_k, t_kk, s_bni, a_bnin, e_bkni],
        "embed_msg_bwd": [a_bnin, m_bkn],
        "embed_combine_bwd": [t_kk, e_bkni, e_bkni, e_bkni],
        "q_scores_bwd": [t_kk, t_kk, t_2k, e_bkni, sc_bni, v_bk, sc_bni],
        "embed_pre_sp_bwd": [t_k, t_k, t_kk, s_bni, s_bni, e_bkni],
        "embed_msg_sp_bwd": [
            jax.ShapeDtypeStruct((b, k, ni), f32),  # d_chunk [B,K,NC]
            jax.ShapeDtypeStruct((n,), f32),        # src [EC]
            jax.ShapeDtypeStruct((n,), f32),        # dst [EC]
            jax.ShapeDtypeStruct((b, n), f32),      # w [B,EC]
        ],
    }
    return table[stage]


def stage_fn(stage: str, *, use_pallas: bool):
    """The callable to lower for `stage` (tuple-returning for PJRT)."""
    fns = {
        "embed_pre": lambda *xs: (embed_pre(*xs),),
        "embed_msg": lambda *xs: (embed_msg(*xs, use_pallas=use_pallas),),
        "embed_combine": lambda *xs: (embed_combine(*xs, use_pallas=use_pallas),),
        "q_sum": lambda *xs: (q_sum(*xs),),
        "q_scores": lambda *xs: (q_scores(*xs),),
        "a_mask": lambda *xs: (a_mask(*xs),),
        "embed_pre_sp": lambda *xs: (embed_pre_sp(*xs),),
        "embed_msg_sp": lambda *xs: (embed_msg_sp(*xs),),
        "embed_pre_sp_bwd": lambda *xs: tuple(embed_pre_sp_bwd(*xs)),
        "embed_msg_sp_bwd": lambda *xs: (embed_msg_sp_bwd(*xs),),
        "embed_pre_bwd": lambda *xs: tuple(embed_pre_bwd(*xs)),
        "embed_msg_bwd": lambda *xs: (embed_msg_bwd(*xs),),
        "embed_combine_bwd": lambda *xs: tuple(embed_combine_bwd(*xs)),
        "q_scores_bwd": lambda *xs: tuple(q_scores_bwd(*xs)),
    }
    return fns[stage]


STAGE_NUM_OUTPUTS = {
    "embed_pre": 1,
    "embed_msg": 1,
    "embed_combine": 1,
    "q_sum": 1,
    "q_scores": 1,
    "a_mask": 1,
    "embed_pre_sp": 1,
    "embed_msg_sp": 1,
    "embed_pre_sp_bwd": 3,
    "embed_msg_sp_bwd": 1,
    "embed_pre_bwd": 3,
    "embed_msg_bwd": 1,
    "embed_combine_bwd": 3,
    "q_scores_bwd": 5,
}
