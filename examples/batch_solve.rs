//! Batched solve engine demo: pack mixed ER/BA graphs across three
//! scenarios (MVC, MaxCut, MIS) and serve them through the job queue in
//! one run — the API behind `oggm batch-solve`.
//!
//!   cargo run --release --example batch_solve -- --jobs 9 --n 20 --p 2

use oggm::batch::{run_queue, BatchCfg, Job};
use oggm::coordinator::selection::SelectionPolicy;
use oggm::env::Scenario;
use oggm::graph::generators;
use oggm::runtime::{manifest, Runtime};
use oggm::util::cli::Args;
use oggm::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let count = args.get_usize("jobs", 9);
    let n = args.get_usize("n", 20);
    let p = args.get_usize("p", 2);
    let rt = Runtime::new(manifest::default_dir())?;
    let mut rng = Pcg32::new(args.get_u64("seed", 12), 2);

    // Round-robin scenarios over mixed ER/BA graphs: one queue run solves
    // heterogeneous requests by grouping into per-scenario packs.
    let scenarios = [Scenario::Mvc, Scenario::MaxCut, Scenario::Mis];
    let jobs: Vec<Job> = (0..count)
        .map(|i| {
            let graph = if i % 2 == 0 {
                generators::erdos_renyi(n, 0.2, &mut rng)
            } else {
                generators::barabasi_albert(n, 3, &mut rng)
            };
            Job {
                id: format!("{}{}", if i % 2 == 0 { "er" } else { "ba" }, i),
                scenario: scenarios[i % scenarios.len()],
                graph,
            }
        })
        .collect();
    println!("== batch_solve: {count} jobs, |V|={n}, P={p} ==");

    let mut cfg = BatchCfg::new(p, 2);
    if args.has_flag("multi") {
        cfg.policy = SelectionPolicy::AdaptiveMulti;
    }
    let params = oggm::model::Params::init(32, &mut Pcg32::new(13, 2));
    let report = run_queue(&rt, &cfg, &params, &jobs)?;

    for pk in &report.packs {
        println!(
            "pack {}: {} N={} jobs={} capacity={} rounds={} repacks={} sim {:.4}s",
            pk.pack, pk.scenario.name(), pk.bucket_n, pk.jobs, pk.capacity, pk.rounds,
            pk.repacks, pk.sim_time
        );
    }
    for o in &report.outcomes {
        println!(
            "  {:>6} [{:>6}] |V|={} -> solution {} (objective {}, {} evals, {})",
            o.id, o.scenario.name(), o.nodes, o.solution_size, o.objective, o.evaluations,
            if o.valid { "valid" } else { "INVALID" }
        );
    }
    println!("total wall: {:.2}s", report.wall_total);
    Ok(())
}
