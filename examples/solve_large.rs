//! Spatial-parallel inference on a large graph: solves one ER graph that is
//! row-partitioned across P ∈ {1,2,3,6} simulated devices with adaptive
//! multiple-node selection, and compares cover quality + per-evaluation
//! time against the greedy baseline.
//!
//!   cargo run --release --example solve_large -- --n 1488 --params t.oggm

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::Table;
use oggm::coordinator::selection::SelectionPolicy;
use oggm::graph::generators;
use oggm::model::Params;
use oggm::runtime::{manifest, Runtime};
use oggm::util::cli::Args;
use oggm::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 1488);
    let p_list = args.get_usize_list("p", &[1, 2, 3, 6]);

    let rt = Runtime::new(manifest::default_dir())?;
    let mut rng = Pcg32::new(args.get_u64("seed", 5), 1);
    println!("generating ER({n}, 0.15)...");
    let g = generators::erdos_renyi(n, 0.15, &mut rng);
    println!("|V|={} |E|={}", g.n, g.m);

    let params = match args.get("params") {
        Some(p) => Params::load(p, 32)?,
        None => {
            let init = manifest::default_dir().join("params_init.oggm");
            if init.exists() { Params::load(init, 32)? } else { Params::init(32, &mut rng) }
        }
    };

    let mut table = Table::new(
        &format!("spatial-parallel inference, ER({n}, 0.15), adaptive multi-select"),
        &["cover", "evals", "sim_s_per_eval", "total_sim_s"],
    );
    for &p in &p_list {
        let mut cfg = InferCfg::new(p, 2);
        cfg.policy = SelectionPolicy::AdaptiveMulti;
        let res = solve_mvc(&rt, &cfg, &params, &g, n)?;
        table.row(
            format!("P={p}"),
            vec![
                res.solution_size as f64,
                res.evaluations as f64,
                res.sim_time_per_eval,
                res.sim_time_per_eval * res.evaluations as f64,
            ],
        );
        println!(
            "P={p}: cover {} in {} evals, {:.4}s/eval (sim), wall {:.1}s",
            res.solution_size, res.evaluations, res.sim_time_per_eval, res.wall_total
        );
    }
    let greedy = oggm::solvers::greedy_mvc(&g).iter().filter(|&&b| b).count();
    println!("\n{}", table.render());
    println!("greedy baseline cover: {greedy}");
    Ok(())
}
