//! Perf probe: h2d / exec / d2h breakdown of one large policy evaluation.
//! Used for the EXPERIMENTS.md §Perf iteration log.
use oggm::coordinator::{engine::EngineCfg, fwd::forward, shard::shards_for_graph};
use oggm::env::{GraphEnv, MvcEnv};
use oggm::graph::{generators, Partition};
use oggm::model::Params;
use oggm::runtime::Runtime;
use oggm::util::rng::Pcg32;
fn main() {
    let rt = Runtime::new("artifacts").unwrap();
    let mut rng = Pcg32::seeded(1);
    let params = Params::init(32, &mut rng);
    let n = 2496;
    let g = generators::erdos_renyi(n, 0.15, &mut rng);
    let env = MvcEnv::new(g.clone());
    let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
    let part = Partition::new(n, 1);
    let shards = shards_for_graph(part, &g, env.removed_mask(), env.solution_mask(), &cand);
    let cfg = EngineCfg::new(1, 2);
    forward(&rt, &cfg, &params, &shards, false, true).unwrap();
    rt.reset_stats();
    let reps = 5;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        forward(&rt, &cfg, &params, &shards, false, true).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64() / reps as f64;
    let s = rt.stats();
    println!("N={n} P=1 fwd: wall {:.4}s/eval, breakdown over {} execs:", wall, s.executions);
    println!("  h2d  {:.4}s/eval", s.h2d_time.as_secs_f64() / reps as f64);
    println!("  exec {:.4}s/eval", s.exec_time.as_secs_f64() / reps as f64);
    println!("  d2h  {:.4}s/eval", s.d2h_time.as_secs_f64() / reps as f64);
}
