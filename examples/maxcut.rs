//! Extensibility demo (Fig. 1: "users can add new graph problem
//! environments"): the MaxCut environment plugged into the same policy
//! model and distributed evaluation machinery, compared against the
//! classical 1-flip local-search baseline.
//!
//! The policy-guided rollout scores candidates with the distributed
//! structure2vec + Q evaluation (same AOT stages as MVC — the environment
//! only changes the reward/termination semantics and the state tensors'
//! interpretation).
//!
//!   cargo run --release --example maxcut -- --n 100

use oggm::coordinator::engine::EngineCfg;
use oggm::coordinator::fwd::forward;
use oggm::coordinator::shard::shards_for_graph;
use oggm::env::{GraphEnv, MaxCutEnv};
use oggm::graph::{generators, Partition};
use oggm::model::Params;
use oggm::runtime::{manifest, Runtime};
use oggm::util::cli::Args;
use oggm::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 240);
    let p = args.get_usize("p", 2);
    let rt = Runtime::new(manifest::default_dir())?;
    let mut rng = Pcg32::new(args.get_u64("seed", 8), 1);
    let g = generators::erdos_renyi(n, 0.1, &mut rng);
    println!("== MaxCut extensibility demo: ER({n}, 0.1), |E|={} ==", g.m);

    let bucket = rt.manifest.bucket_for(g.n, p, 1)?;
    let part = Partition::new(bucket, p);
    let cfg = EngineCfg::new(p, 2);
    let params = Params::init(32, &mut Pcg32::new(9, 1));

    // Policy-guided greedy rollout: distributed score evaluation, take the
    // best positive-gain candidate among the top-scored nodes.
    let mut env = MaxCutEnv::new(g.clone());
    let mut evals = 0usize;
    while !env.done() {
        let cand: Vec<bool> = (0..g.n).map(|v| env.is_candidate(v)).collect();
        let shards =
            shards_for_graph(part, &g, env.removed_mask(), env.solution_mask(), &cand);
        let out = forward(&rt, &cfg, &params, &shards, false, true)?;
        evals += 1;
        // Among the 8 best-scored candidates, take the best positive gain.
        let picked = oggm::coordinator::selection::top_d(
            &out.scores[..g.n],
            |v| env.is_candidate(v),
            8,
        );
        let best = picked
            .into_iter()
            .filter(|&v| env.gain(v) > 0)
            .max_by_key(|&v| env.gain(v));
        match best {
            Some(v) => {
                env.step(v);
            }
            None => break, // no improving move among top-scored: stop
        }
    }
    println!("policy-guided rollout: cut {} after {evals} distributed evals",
             env.cut_value());

    // Classical baseline: randomized greedy + 1-flip local search.
    let (_side, cut) = oggm::solvers::localsearch::local_search_maxcut(&g, &mut rng, 200);
    println!("local-search baseline: cut {cut}");
    println!("edges total: {} (any cut <= |E|)", g.m);
    Ok(())
}
