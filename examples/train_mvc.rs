//! End-to-end training driver (the repo's E2E validation run, recorded in
//! EXPERIMENTS.md): trains the DQN + structure2vec agent on ER(20, 0.15)
//! graphs for a few hundred steps across P simulated devices, periodically
//! evaluates the mean approximation ratio on 10 held-out test graphs, and
//! writes the loss/ratio learning curve to CSV.
//!
//!   cargo run --release --example train_mvc -- --steps 400 --p 2 --tau 4 \
//!       --out curve.csv --params trained.oggm

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::metrics::{approx_ratio, write_curve_csv, CurvePoint};
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::graph::{generators, Graph};
use oggm::model::Params;
use oggm::runtime::{manifest, Runtime};
use oggm::util::cli::Args;
use oggm::util::rng::Pcg32;
use std::time::Duration;

fn eval_ratio(
    rt: &Runtime,
    params: &Params,
    tests: &[(Graph, usize)],
    p: usize,
) -> anyhow::Result<f64> {
    let cfg = InferCfg::new(p, 2);
    let mut total = 0.0;
    for (g, opt) in tests {
        let res = solve_mvc(rt, &cfg, params, g, 24)?;
        total += approx_ratio(res.solution_size, *opt);
    }
    Ok(total / tests.len() as f64)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps_target = args.get_usize("steps", 400);
    let p = args.get_usize("p", 2);
    let tau = args.get_usize("tau", 4);
    let eval_every = args.get_usize("eval-every", 25);
    let seed = args.get_u64("seed", 2021);

    let rt = Runtime::new(manifest::default_dir())?;
    println!("== train_mvc: E2E driver (P={p}, tau={tau}, {steps_target} steps) ==");

    // Datasets: train on 16 ER(20) graphs; test on 10 held-out ER(20).
    let mut rng = Pcg32::new(seed, 1);
    let train_graphs: Vec<_> =
        (0..16).map(|_| generators::erdos_renyi(20, 0.15, &mut rng)).collect();
    let tests: Vec<(Graph, usize)> = (0..10)
        .map(|_| {
            let g = generators::erdos_renyi(20, 0.15, &mut rng);
            let opt = oggm::solvers::exact_mvc(&g, Duration::from_secs(10)).size;
            (g, opt)
        })
        .collect();

    let mut cfg = TrainCfg::new(p, 24);
    cfg.seed = seed;
    cfg.hyper.lr = args.get_f64("lr", 1e-3) as f32;
    cfg.hyper.grad_iters = tau;
    cfg.hyper.eps_decay_steps = steps_target / 2;
    let params0 = Params::init(32, &mut Pcg32::new(seed, 2));
    let mut trainer = Trainer::new(&rt, cfg, train_graphs, params0)?;

    let ratio0 = eval_ratio(&rt, &trainer.params, &tests, p)?;
    println!("step {:>5}  ratio {:.4}  (untrained)", 0, ratio0);
    let mut curve = vec![CurvePoint { step: 0, ratio: ratio0, loss: None }];

    let mut recent_loss: Option<f32> = None;
    let mut recent_sim = 0.0f64;
    while trainer.global_step < steps_target {
        // Pull step records out of the episode; evaluation happens on the
        // eval_every grid (the paper measures every 10 training steps).
        let mut pending_evals: Vec<(usize, Option<f32>)> = Vec::new();
        trainer.run_episodes(1, |rec| {
            if rec.loss.is_some() {
                recent_loss = rec.loss;
            }
            recent_sim += rec.sim_step_time;
            if rec.global_step % eval_every == 0 {
                pending_evals.push((rec.global_step, rec.loss));
            }
        })?;
        for (step, loss) in pending_evals {
            let ratio = eval_ratio(&rt, &trainer.params, &tests, p)?;
            println!(
                "step {step:>5}  ratio {ratio:.4}  loss {}  mean-sim-step {:.4}s",
                loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
                recent_sim / step.max(1) as f64,
            );
            curve.push(CurvePoint { step, ratio, loss: loss.map(|l| l as f64) });
        }
    }

    let final_ratio = eval_ratio(&rt, &trainer.params, &tests, p)?;
    println!("\nfinal mean approx ratio over 10 test graphs: {final_ratio:.4}");
    println!("replay buffer: {} tuples, {} KiB (compressed)",
             trainer.replay_len(), trainer.replay_bytes() / 1024);

    if let Some(out) = args.get("out") {
        write_curve_csv(out, &curve)?;
        println!("learning curve written to {out}");
    }
    if let Some(ppath) = args.get("params") {
        trainer.params.save(ppath)?;
        println!("trained parameters written to {ppath}");
    }
    Ok(())
}
