//! Quickstart: train a small MVC agent on 20-node ER graphs across 2
//! simulated devices, then solve an unseen graph and compare against the
//! classical baselines.
//!
//!   make artifacts && cargo run --release --example quickstart

use oggm::coordinator::infer::{solve_mvc, InferCfg};
use oggm::coordinator::selection::SelectionPolicy;
use oggm::coordinator::train::{TrainCfg, Trainer};
use oggm::graph::generators;
use oggm::model::Params;
use oggm::runtime::{manifest, Runtime};
use oggm::util::rng::Pcg32;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(manifest::default_dir())?;
    println!("== OpenGraphGym-MG quickstart (platform: {}) ==\n", rt.platform());

    // 1. Training dataset: eight ER(20, 0.15) graphs (paper §6.2 setup).
    let mut rng = Pcg32::seeded(42);
    let graphs: Vec<_> =
        (0..8).map(|_| generators::erdos_renyi(20, 0.15, &mut rng)).collect();

    // 2. Train on P=2 simulated devices.
    let mut cfg = TrainCfg::new(2, 24);
    cfg.hyper.lr = 1e-3;
    cfg.hyper.grad_iters = 4; // §4.5.2: multiple gradient iterations
    cfg.seed = 7;
    let params0 = Params::init(32, &mut Pcg32::seeded(43));
    let mut trainer = Trainer::new(&rt, cfg, graphs, params0)?;
    println!("training: 25 episodes on ER(20, 0.15), P=2, tau=4 ...");
    let mut last = None;
    trainer.run_episodes(25, |rec| {
        if rec.loss.is_some() {
            last = rec.loss;
        }
        if rec.global_step % 25 == 0 {
            println!(
                "  step {:>4}  loss {}",
                rec.global_step,
                rec.loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into())
            );
        }
    })?;
    println!("  final loss: {:?}\n", last);

    // 3. Solve an unseen graph with the trained policy.
    let g = generators::erdos_renyi(20, 0.15, &mut rng);
    let mut icfg = InferCfg::new(2, 2);
    icfg.policy = SelectionPolicy::AdaptiveMulti;
    let res = solve_mvc(&rt, &icfg, &trainer.params, &g, 24)?;

    // 4. Baselines.
    let greedy = oggm::solvers::greedy_mvc(&g).iter().filter(|&&b| b).count();
    let approx = oggm::solvers::two_approx_mvc(&g).iter().filter(|&&b| b).count();
    let exact = oggm::solvers::exact_mvc(&g, Duration::from_secs(10));

    println!("unseen ER(20, 0.15) graph with {} edges:", g.m);
    println!("  RL agent cover:  {} ({} policy evals)", res.solution_size, res.evaluations);
    println!("  greedy cover:    {greedy}");
    println!("  2-approx cover:  {approx}");
    println!("  optimal cover:   {} ({})", exact.size,
             if exact.optimal { "proven" } else { "cutoff" });
    println!(
        "  approx ratio:    {:.3}",
        oggm::coordinator::metrics::approx_ratio(res.solution_size, exact.size)
    );
    Ok(())
}
