//! Stub of the vendored `xla-rs` PJRT bindings.
//!
//! The real bindings (HLO-text → XlaComputation → PjRtLoadedExecutable on
//! the PJRT CPU client, adapted from /opt/xla-example/load_hlo) live in the
//! accelerator build image and are dropped into this directory when
//! present. Containers without that image still need `cargo build` and
//! `cargo test -q` to succeed, so this stub mirrors the exact API surface
//! `rust/src/runtime/exec.rs` uses and fails at *client construction* with
//! an actionable message. Every artifact-dependent test in the repo first
//! checks for `artifacts/manifest.tsv` and skips before constructing a
//! client, so the full test suite passes against the stub.
//!
//! NOTE for vendoring the real bindings: besides the original surface
//! (`execute_b`, `to_literal_sync`, …), the runtime's device-resident path
//! now also needs `PjRtLoadedExecutable::execute_untupled` — `execute_b`
//! with `xla::ExecuteOptions::untuple_result = true`, returning the tuple
//! leaves as separate `PjRtBuffer`s. The C glue change mirrors
//! `execute_b`'s exactly (see DESIGN.md §5).

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type of the bindings; implements `std::error::Error` so call
/// sites can `?` it into `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable — this build links the offline \
         xla-rs stub; vendor the real bindings into third_party/xla-rs \
         (see DESIGN.md §Build) to execute compiled artifacts"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }

    /// Execute with `ExecuteOptions.untuple_result = true`: the result tuple
    /// is split on device and returned as one leaf `PjRtBuffer` per output
    /// (outer Vec: device; inner Vec: outputs). This is what lets the
    /// runtime chain stage outputs into the next stage's inputs without a
    /// host round-trip (see rust/src/runtime/exec.rs `execute_d`).
    pub fn execute_untupled<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_untupled")
    }
}

/// Host-side literal (tuple or dense array).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_guidance() {
        let err = PjRtClient::cpu().err().expect("stub must refuse to construct");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("third_party/xla-rs"), "{msg}");
    }
}
