//! Offline vendored stand-in for the `anyhow` crate (the build image has no
//! crates.io access; see DESIGN.md §Build). Implements exactly the subset
//! this repo uses — `Error`, `Result`, `Context`, `bail!` / `ensure!` /
//! `anyhow!`, and the `{:#}` cause-chain formatting — with call-compatible
//! signatures, so the real crate can be swapped in when a registry is
//! available.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the usual default-parameter alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus a cause chain.
pub struct Error(Box<ErrorImpl>);

struct ErrorImpl {
    msg: String,
    source: Option<Error>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error(Box::new(ErrorImpl { msg: m.to_string(), source: None }))
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error(Box::new(ErrorImpl { msg: ctx.to_string(), source: Some(self) }))
    }

    /// Messages from outermost context to root cause.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.0.msg.as_str());
            cur = e.0.source.as_ref();
        }
        out
    }

    /// The root cause's message (innermost in the chain).
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full cause chain, colon-separated (anyhow's style).
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.0.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `?`-conversion from any std error; the source chain is flattened into
// owned messages (the borrowed sources cannot be retained). `Error` itself
// deliberately does NOT implement std::error::Error, which keeps this
// blanket impl coherent with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().unwrap());
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options. A single `Into<Error>` bound covers both std errors and
/// `Error` itself without overlapping impls.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an `Error` from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted `Error`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted `Error` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err().into());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "u")).unwrap_err();
        assert_eq!(format!("{e}"), "missing u");
    }

    #[test]
    fn macros_format() {
        fn f(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too large: {}", x);
            }
            Ok(())
        }
        assert!(f(5).is_ok());
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too large: 11");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err::<(), std::io::Error>(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "no such file");
    }
}
