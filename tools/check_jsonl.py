#!/usr/bin/env python3
"""Validate an `oggm serve` JSONL outcome stream (CI smoke check).

Usage: check_jsonl.py <file> [--allow-missing] [--allow-rejects] [--allow-errors]

Schema (README §serve): one JSON object per line.

* Outcome lines carry id/scenario/nodes/edges/pack/solution/solution_size/
  objective/valid/evaluations/selections, plus (since the TCP front door)
  the service "job" handle, "tenant", and "wait_ms" queue-wait.
* Error lines carry "id" and "error" instead of outcome fields.
* Reject lines (backpressure) add "rejected": true with queue context:
  either queue_depth + tenant_load (quota reject) or queue_cap (admission
  queue full).
* Stats lines ({"op": "stats", "stats": {...}}) answer a client stats
  probe with numeric counters.
* Drain acks ({"op": "drain", "draining": true, "pending": N,
  "in_flight": M}) answer a graceful-drain request.

Exits non-zero on any malformed line, schema violation, invalid solution
flag, error line (unless --allow-errors: the TCP smoke without artifacts
degrades to schema-valid "runtime startup failed" error lines), or reject
line (unless --allow-rejects). --allow-missing exits 0 when the file does
not exist (serve skipped in check mode without artifacts).
"""

import json
import sys
from pathlib import Path

OUTCOME_KEYS = {
    "scenario": str,
    "nodes": (int, float),
    "edges": (int, float),
    "pack": (int, float),
    "solution": list,
    "solution_size": (int, float),
    "objective": (int, float),
    "valid": bool,
    "evaluations": (int, float),
    "selections": (int, float),
}
# Optional service-layer keys (present on every line the TCP front door
# emits; the file-mode stream may omit them on older captures).
SERVICE_KEYS = {
    "job": (int, float),
    "tenant": (int, float),
    "wait_ms": (int, float),
}
SCENARIOS = {"mvc", "maxcut", "mis"}


def fail(lineno, msg):
    print(f"check_jsonl: line {lineno}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_service_keys(lineno, obj):
    for key, ty in SERVICE_KEYS.items():
        if key in obj:
            if not isinstance(obj[key], ty) or isinstance(obj[key], bool):
                fail(lineno, f"'{key}' has wrong type: {obj[key]!r}")
            if obj[key] < 0:
                fail(lineno, f"'{key}' must be non-negative: {obj[key]!r}")


def check_stats(lineno, obj):
    stats = obj.get("stats")
    if not isinstance(stats, dict) or not stats:
        fail(lineno, "stats line missing a non-empty 'stats' object")
    for key, val in stats.items():
        if key == "launch_causes":
            if not isinstance(val, dict):
                fail(lineno, "'launch_causes' must be an object")
            continue
        if not isinstance(val, (int, float)) or isinstance(val, bool):
            fail(lineno, f"stats counter '{key}' is not numeric: {val!r}")


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    flags = {a for a in sys.argv[1:] if a.startswith("--")}
    if len(args) != 1:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    path = Path(args[0])
    if not path.exists():
        if "--allow-missing" in flags:
            print(f"check_jsonl: {path} missing, allowed (serve skipped)")
            sys.exit(0)
        print(f"check_jsonl: {path} does not exist", file=sys.stderr)
        sys.exit(1)

    outcomes = errors = rejects = stats_lines = drain_lines = 0
    for lineno, raw in enumerate(path.read_text().splitlines(), start=1):
        if not raw.strip():
            fail(lineno, "blank line in JSONL stream")
        try:
            obj = json.loads(raw)
        except json.JSONDecodeError as e:
            fail(lineno, f"not valid JSON: {e}")
        if not isinstance(obj, dict):
            fail(lineno, "line is not a JSON object")
        if obj.get("op") == "stats":
            check_stats(lineno, obj)
            stats_lines += 1
            continue
        if obj.get("op") == "drain":
            if obj.get("draining") is not True:
                fail(lineno, "drain ack missing 'draining': true")
            for key in ("pending", "in_flight"):
                if not isinstance(obj.get(key), (int, float)) or isinstance(obj.get(key), bool):
                    fail(lineno, f"drain ack '{key}' is not numeric: {obj.get(key)!r}")
            drain_lines += 1
            continue
        if not isinstance(obj.get("id"), str) or not obj["id"]:
            fail(lineno, "missing/empty 'id'")
        check_service_keys(lineno, obj)
        if obj.get("rejected") is True:
            if not isinstance(obj.get("error"), str) or not obj["error"]:
                fail(lineno, "reject line must carry a non-empty 'error'")
            has_quota_ctx = all(
                isinstance(obj.get(k), (int, float)) and not isinstance(obj.get(k), bool)
                for k in ("queue_depth", "tenant_load")
            )
            has_queue_ctx = isinstance(obj.get("queue_cap"), (int, float)) and not isinstance(
                obj.get("queue_cap"), bool
            )
            if not (has_quota_ctx or has_queue_ctx):
                fail(lineno, "reject line missing queue_depth+tenant_load or queue_cap")
            rejects += 1
            continue
        if "error" in obj:
            if not isinstance(obj["error"], str) or not obj["error"]:
                fail(lineno, "'error' must be a non-empty string")
            errors += 1
            continue
        for key, ty in OUTCOME_KEYS.items():
            if key not in obj:
                fail(lineno, f"outcome line missing '{key}'")
            if not isinstance(obj[key], ty) or (ty is not bool and isinstance(obj[key], bool)):
                fail(lineno, f"'{key}' has wrong type: {obj[key]!r}")
        if obj["scenario"] not in SCENARIOS:
            fail(lineno, f"unknown scenario {obj['scenario']!r}")
        sol = obj["solution"]
        if any(not isinstance(v, int) or isinstance(v, bool) or v < 0 for v in sol):
            fail(lineno, "solution must be non-negative integers")
        if sol != sorted(sol) or len(set(sol)) != len(sol):
            fail(lineno, "solution must be strictly ascending node ids")
        if len(sol) != obj["solution_size"]:
            fail(lineno, "solution_size disagrees with the solution list")
        if sol and max(sol) >= obj["nodes"]:
            fail(lineno, "solution node id out of range")
        if not obj["valid"]:
            fail(lineno, f"job {obj['id']} reported an invalid solution")
        outcomes += 1

    if outcomes + errors + rejects == 0:
        print("check_jsonl: stream has no job lines", file=sys.stderr)
        sys.exit(1)
    if errors and "--allow-errors" not in flags:
        # Error lines are schema-valid, but a smoke run must be clean.
        print(
            f"check_jsonl: FAIL — {errors} error lines in the stream "
            f"({outcomes} outcomes were fine)",
            file=sys.stderr,
        )
        sys.exit(1)
    if rejects and "--allow-rejects" not in flags:
        print(
            f"check_jsonl: FAIL — {rejects} reject lines in the stream "
            f"(pass --allow-rejects if backpressure is expected)",
            file=sys.stderr,
        )
        sys.exit(1)
    extra = f", {errors} error lines" if errors else ""
    extra += f", {rejects} rejects" if rejects else ""
    extra += f", {stats_lines} stats lines" if stats_lines else ""
    extra += f", {drain_lines} drain acks" if drain_lines else ""
    print(f"check_jsonl: OK ({outcomes} outcomes{extra})")


if __name__ == "__main__":
    main()
