#!/usr/bin/env python3
"""Validate an `oggm serve` JSONL outcome stream (CI smoke check).

Usage: check_jsonl.py <file> [--allow-missing]

Schema (README §serve): one JSON object per line. Every line carries "id";
outcome lines add scenario/nodes/edges/pack/solution/solution_size/
objective/valid/evaluations/selections (+ the service "job" handle), error
lines carry "error" instead. Exits non-zero on any malformed line, schema
violation, or invalid solution flag; --allow-missing exits 0 when the file
does not exist (serve skipped in check mode without artifacts).
"""

import json
import sys
from pathlib import Path

OUTCOME_KEYS = {
    "scenario": str,
    "nodes": (int, float),
    "edges": (int, float),
    "pack": (int, float),
    "solution": list,
    "solution_size": (int, float),
    "objective": (int, float),
    "valid": bool,
    "evaluations": (int, float),
    "selections": (int, float),
}
SCENARIOS = {"mvc", "maxcut", "mis"}


def fail(lineno, msg):
    print(f"check_jsonl: line {lineno}: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    flags = {a for a in sys.argv[1:] if a.startswith("--")}
    if len(args) != 1:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    path = Path(args[0])
    if not path.exists():
        if "--allow-missing" in flags:
            print(f"check_jsonl: {path} missing, allowed (serve skipped)")
            sys.exit(0)
        print(f"check_jsonl: {path} does not exist", file=sys.stderr)
        sys.exit(1)

    outcomes = errors = 0
    for lineno, raw in enumerate(path.read_text().splitlines(), start=1):
        if not raw.strip():
            fail(lineno, "blank line in JSONL stream")
        try:
            obj = json.loads(raw)
        except json.JSONDecodeError as e:
            fail(lineno, f"not valid JSON: {e}")
        if not isinstance(obj, dict):
            fail(lineno, "line is not a JSON object")
        if not isinstance(obj.get("id"), str) or not obj["id"]:
            fail(lineno, "missing/empty 'id'")
        if "error" in obj:
            if not isinstance(obj["error"], str) or not obj["error"]:
                fail(lineno, "'error' must be a non-empty string")
            errors += 1
            continue
        for key, ty in OUTCOME_KEYS.items():
            if key not in obj:
                fail(lineno, f"outcome line missing '{key}'")
            if not isinstance(obj[key], ty) or (ty is not bool and isinstance(obj[key], bool)):
                fail(lineno, f"'{key}' has wrong type: {obj[key]!r}")
        if obj["scenario"] not in SCENARIOS:
            fail(lineno, f"unknown scenario {obj['scenario']!r}")
        sol = obj["solution"]
        if any(not isinstance(v, int) or isinstance(v, bool) or v < 0 for v in sol):
            fail(lineno, "solution must be non-negative integers")
        if sol != sorted(sol) or len(set(sol)) != len(sol):
            fail(lineno, "solution must be strictly ascending node ids")
        if len(sol) != obj["solution_size"]:
            fail(lineno, "solution_size disagrees with the solution list")
        if sol and max(sol) >= obj["nodes"]:
            fail(lineno, "solution node id out of range")
        if not obj["valid"]:
            fail(lineno, f"job {obj['id']} reported an invalid solution")
        outcomes += 1

    if outcomes + errors == 0:
        print("check_jsonl: stream is empty", file=sys.stderr)
        sys.exit(1)
    if errors:
        # Error lines are schema-valid, but a smoke run must be clean.
        print(
            f"check_jsonl: FAIL — {errors} error lines in the stream "
            f"({outcomes} outcomes were fine)",
            file=sys.stderr,
        )
        sys.exit(1)
    print(f"check_jsonl: OK ({outcomes} outcomes)")


if __name__ == "__main__":
    main()
