#!/usr/bin/env python3
"""Markdown link checker for the repo docs (stdlib only, used by CI).

Scans the given markdown files (default: README.md DESIGN.md EXPERIMENTS.md
PAPER.md) for inline links/images `[text](target)` and verifies that every
*relative* target exists on disk (anchors are stripped; `http(s)://` and
`mailto:` targets are skipped — the container is offline). Also verifies
that backtick-quoted repo paths that look like files (contain a `/` and an
extension) exist, which keeps DESIGN/EXPERIMENTS references like
`rust/src/coordinator/fwd.rs` honest as the tree moves.

Exit code 0 when clean, 1 with a listing of broken references otherwise.
"""

import os
import re
import sys

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+\.[A-Za-z0-9]{1,5})`")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")

# Backticked paths that are templates/outputs, not checked-in files.
PATH_ALLOW_MISSING = (
    "artifacts/",          # build outputs (make artifacts)
    "results.json",
    "params.oggm",
    "trained.oggm",
    "jobs.txt",
    "graphs/",
    "bench_results.jsonl",
    "BENCH_",              # bench outputs
)


def check_file(path: str) -> list:
    broken = []
    root = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.join(root, rel)):
                broken.append(f"{path}:{lineno}: broken link -> {target}")
        for m in PATH_RE.finditer(line):
            rel = m.group(1)
            if rel.startswith(PATH_ALLOW_MISSING) or any(
                rel.startswith(p) or p.rstrip("/") in rel for p in PATH_ALLOW_MISSING
            ):
                continue
            # Docs shorthand: module paths relative to rust/src/ or python/.
            candidates = [rel, os.path.join("rust", "src", rel), os.path.join("python", rel)]
            if not any(os.path.exists(os.path.join(root, c)) for c in candidates):
                broken.append(f"{path}:{lineno}: missing referenced path -> {rel}")
    return broken


def main() -> int:
    files = sys.argv[1:] or ["README.md", "DESIGN.md", "EXPERIMENTS.md", "PAPER.md"]
    broken = []
    for path in files:
        if not os.path.exists(path):
            broken.append(f"{path}: file not found")
            continue
        broken.extend(check_file(path))
    if broken:
        print("check_links: FAIL")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"check_links: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
