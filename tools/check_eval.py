#!/usr/bin/env python3
"""Validate an `oggm eval` JSON quality report (CI smoke check).

Usage: check_eval.py <report.json> [--max-ratio X] [--require-baselines N]
                     [--allow-missing]

Schema (README §eval / rust/src/analysis/quality.rs):

* Top level: "scenario" (mvc|maxcut|mis), "instances" (non-empty list),
  "summary" {"instances", "worst_ratio", "infeasible", "solvers"}.
* Each instance: "name", "nodes", "edges", "reference" {"solver",
  "objective", "optimal"}, "scores" (non-empty list).
* Each score: "solver", "objective", "size", "feasible", "optimal",
  "ratio", "wall_s"; RL scores add "per_step_ms"/"evaluations".

Exits non-zero on any schema violation, any score with "feasible": false,
any feasible ratio above --max-ratio (default 2.5), or fewer than
--require-baselines distinct non-RL solvers (default 2). --allow-missing
exits 0 when the report does not exist (eval skipped in check mode).
"""

import json
import sys
from pathlib import Path

SCENARIOS = {"mvc", "maxcut", "mis"}
SCORE_KEYS = {
    "solver": str,
    "objective": (int, float),
    "size": (int, float),
    "feasible": bool,
    "optimal": bool,
    "ratio": (int, float),
    "wall_s": (int, float),
}
REFERENCE_KEYS = {
    "solver": str,
    "objective": (int, float),
    "optimal": bool,
}
SUMMARY_KEYS = {
    "instances": (int, float),
    "worst_ratio": (int, float),
    "infeasible": (int, float),
    "solvers": dict,
}


def fail(where, msg):
    print(f"check_eval: {where}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_keys(where, obj, schema):
    for key, ty in schema.items():
        if key not in obj:
            fail(where, f"missing '{key}'")
        if not isinstance(obj[key], ty) or (ty is not bool and isinstance(obj[key], bool)):
            fail(where, f"'{key}' has wrong type: {obj[key]!r}")


def arg_value(flags, name, default):
    for flag in flags:
        if flag.startswith(f"{name}="):
            return flag.split("=", 1)[1]
    return default


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    flags = {a for a in sys.argv[1:] if a.startswith("--")}
    if len(args) != 1:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    max_ratio = float(arg_value(flags, "--max-ratio", "2.5"))
    require_baselines = int(arg_value(flags, "--require-baselines", "2"))
    path = Path(args[0])
    if not path.exists():
        if "--allow-missing" in flags:
            print(f"check_eval: {path} missing, allowed (eval skipped)")
            sys.exit(0)
        fail(str(path), "report does not exist")

    try:
        report = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        fail(str(path), f"not valid JSON: {e}")
    if not isinstance(report, dict):
        fail(str(path), "report is not a JSON object")
    if report.get("scenario") not in SCENARIOS:
        fail("top level", f"unknown scenario {report.get('scenario')!r}")
    instances = report.get("instances")
    if not isinstance(instances, list) or not instances:
        fail("top level", "'instances' must be a non-empty list")
    summary = report.get("summary")
    if not isinstance(summary, dict):
        fail("top level", "'summary' must be an object")
    check_keys("summary", summary, SUMMARY_KEYS)

    baselines = set()
    infeasible = 0
    worst_ratio = 1.0
    scores_seen = 0
    for i, inst in enumerate(instances):
        where = f"instance {i}"
        if not isinstance(inst, dict):
            fail(where, "not a JSON object")
        if not isinstance(inst.get("name"), str) or not inst["name"]:
            fail(where, "missing/empty 'name'")
        where = f"instance {inst['name']}"
        for key in ("nodes", "edges"):
            if not isinstance(inst.get(key), (int, float)) or isinstance(inst.get(key), bool):
                fail(where, f"'{key}' is not numeric")
        ref = inst.get("reference")
        if not isinstance(ref, dict):
            fail(where, "'reference' must be an object")
        check_keys(f"{where} reference", ref, REFERENCE_KEYS)
        scores = inst.get("scores")
        if not isinstance(scores, list) or not scores:
            fail(where, "'scores' must be a non-empty list")
        for score in scores:
            if not isinstance(score, dict):
                fail(where, "score is not a JSON object")
            check_keys(f"{where} score", score, SCORE_KEYS)
            scores_seen += 1
            solver = score["solver"]
            if solver != "rl":
                baselines.add(solver)
            if not score["feasible"]:
                infeasible += 1
                print(
                    f"check_eval: {where}: solver {solver} INFEASIBLE "
                    f"(objective {score['objective']})",
                    file=sys.stderr,
                )
                continue
            if score["ratio"] < 1.0:
                fail(where, f"solver {solver} ratio {score['ratio']} below 1.0")
            worst_ratio = max(worst_ratio, score["ratio"])
            if score["ratio"] > max_ratio:
                fail(
                    where,
                    f"solver {solver} ratio {score['ratio']:.4f} exceeds "
                    f"--max-ratio {max_ratio}",
                )

    if infeasible:
        fail("report", f"{infeasible} scores failed feasibility validation")
    if int(summary["infeasible"]) != 0:
        fail("summary", f"summary reports {summary['infeasible']} infeasible scores")
    if len(baselines) < require_baselines:
        fail(
            "report",
            f"only {len(baselines)} distinct baselines ({sorted(baselines)}), "
            f"need {require_baselines}",
        )
    print(
        f"check_eval: OK ({len(instances)} instances, {scores_seen} scores, "
        f"baselines {sorted(baselines)}, worst ratio {worst_ratio:.4f})"
    )


if __name__ == "__main__":
    main()
