#!/usr/bin/env python3
"""Minimal TCP client for the `oggm serve --listen` smoke (CI).

Usage: serve_client.py HOST:PORT [--jobs N] [--stats] [--drain] [--out FILE]
                       [--expect-errors] [--connect-timeout SECS]

Connects (retrying while the server starts up), sends N newline-delimited
job requests (the same grammar `oggm serve` reads from files), optionally
a {"op": "stats"} probe and/or a {"op": "drain"} request, half-closes the
write side (unless --drain: a graceful drain must end the connection with
NO client-side close), and reads the JSONL response stream to EOF.
Validates that:

* exactly one response line arrives per job, ids matching what was sent;
* responses are outcomes (or, with --expect-errors, error lines — the
  degraded no-artifacts mode where the solver runtime fails to start but
  the network front door still answers every job);
* a stats line arrives iff --stats was sent, a drain ack iff --drain;
* the server closes the connection cleanly (clean shutdown / drain).

Writes the raw stream to --out (default stdout) for deeper schema checks
via check_jsonl.py. Exits non-zero on any violation.
"""

import json
import socket
import sys
import time

SCENARIOS = ["mvc", "mis", "maxcut"]


def fail(msg):
    print(f"serve_client: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def parse_args(argv):
    opts = {
        "jobs": 6,
        "stats": False,
        "drain": False,
        "out": None,
        "expect_errors": False,
        "timeout": 20.0,
    }
    positional = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--jobs":
            opts["jobs"] = int(argv[i + 1])
            i += 2
        elif a == "--stats":
            opts["stats"] = True
            i += 1
        elif a == "--drain":
            opts["drain"] = True
            i += 1
        elif a == "--out":
            opts["out"] = argv[i + 1]
            i += 2
        elif a == "--expect-errors":
            opts["expect_errors"] = True
            i += 1
        elif a == "--connect-timeout":
            opts["timeout"] = float(argv[i + 1])
            i += 2
        else:
            positional.append(a)
            i += 1
    if len(positional) != 1 or ":" not in positional[0]:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    host, port = positional[0].rsplit(":", 1)
    return host, int(port), opts


def connect(host, port, timeout):
    deadline = time.monotonic() + timeout
    while True:
        try:
            return socket.create_connection((host, port), timeout=5.0)
        except OSError as e:
            if time.monotonic() >= deadline:
                fail(f"could not connect to {host}:{port} within {timeout}s: {e}")
            time.sleep(0.2)


def main():
    host, port, opts = parse_args(sys.argv[1:])
    sock = connect(host, port, opts["timeout"])
    sock.settimeout(120.0)

    sent_ids = []
    lines = []
    for i in range(opts["jobs"]):
        jid = f"c{i}"
        sent_ids.append(jid)
        lines.append(
            f"gen er n=20 rho=0.2 seed={40 + i} id={jid} {SCENARIOS[i % len(SCENARIOS)]}\n"
        )
    if opts["stats"]:
        lines.append('{"op": "stats"}\n')
    if opts["drain"]:
        lines.append('{"op": "drain"}\n')
    sock.sendall("".join(lines).encode())
    if not opts["drain"]:
        # Half-close: end-of-stream flushes our open packs server-side and
        # (with --max-conns 1) lets the server exit once everything drains.
        sock.shutdown(socket.SHUT_WR)
    # With --drain the write side stays OPEN: the graceful drain itself
    # must flush our packs, stream every outcome, and close the socket.

    raw = b""
    while True:
        chunk = sock.recv(65536)
        if not chunk:
            break
        raw += chunk
    sock.close()

    text = raw.decode()
    if opts["out"]:
        with open(opts["out"], "w") as f:
            f.write(text)
    else:
        sys.stdout.write(text)

    got_ids, stats_lines, drain_lines, error_lines, outcome_lines = [], 0, 0, 0, 0
    for lineno, line in enumerate(text.splitlines(), start=1):
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"response line {lineno} is not JSON: {e}")
        if obj.get("op") == "stats":
            stats_lines += 1
            continue
        if obj.get("op") == "drain":
            if obj.get("draining") is not True:
                fail(f"drain ack line {lineno} missing 'draining': true: {line}")
            drain_lines += 1
            continue
        if not isinstance(obj.get("id"), str):
            fail(f"response line {lineno} has no id: {line}")
        got_ids.append(obj["id"])
        if "error" in obj:
            error_lines += 1
        else:
            outcome_lines += 1

    if sorted(got_ids) != sorted(sent_ids):
        fail(f"sent ids {sent_ids}, got {sorted(got_ids)}")
    if stats_lines != (1 if opts["stats"] else 0):
        fail(f"expected {'one' if opts['stats'] else 'no'} stats line, got {stats_lines}")
    if drain_lines != (1 if opts["drain"] else 0):
        fail(f"expected {'one' if opts['drain'] else 'no'} drain ack, got {drain_lines}")
    if opts["expect_errors"]:
        if outcome_lines:
            fail(f"{outcome_lines} outcome lines where only errors were expected")
    elif error_lines:
        fail(f"{error_lines} jobs came back as errors")
    kind = "error lines (degraded mode)" if opts["expect_errors"] else "outcomes"
    how = "drained" if opts["drain"] else "clean EOF"
    print(f"serve_client: OK — {len(got_ids)} {kind}, {how}", file=sys.stderr)


if __name__ == "__main__":
    main()
